"""Known-bad time-unit flow fixture: one confusion class per function.

tests/test_analysis.py asserts the exact line of every finding — keep
line numbers stable when editing.
"""


def mixes_add(start_ns, timeout_us):
    deadline = start_ns + timeout_us        # line 9: ns + us
    return deadline


def wrong_assign(duration_us):
    duration_ns = duration_us               # line 14: us into *_ns name
    return duration_ns


def wrong_kwarg(run, window_ns):
    run(window_us=window_ns)                # line 19: kwarg unit clash


def bad_literal(report):
    return report(time_unit="seconds")      # line 23: not in TIME_UNITS


def bad_compare(t_ns, t_cycles):
    return t_ns < t_cycles                  # line 27: cross-unit compare


def bad_cycles_call(hw, lat_ns):
    return hw.cycles_ns(lat_ns)             # line 31: cycles_ns on ns
