"""Known-good time-unit flow fixture: explicit conversions only."""


def convert(duration_us, hw, comp_cycles):
    duration_ns = duration_us * 1e3          # us -> ns
    t_ns = hw.cycles_ns(comp_cycles)         # cycles -> ns
    total_ns = duration_ns + t_ns            # ns + ns
    back_us = total_ns / 1e3                 # ns -> us
    return total_ns, back_us


def wire(frag_bytes, ns_per_byte):
    dur_ns = frag_bytes * ns_per_byte        # bytes * ns/byte -> ns
    return dur_ns


def whitelisted(report):
    return report(time_unit="ns")
