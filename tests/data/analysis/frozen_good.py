"""Known-good frozen-spec / fixed-shape fixture."""


def evolve(spec, scale):
    longer = spec.replace(duration_us=spec.duration_us * scale)
    return longer


def collect(xp, values, mask):
    return xp.where(mask, values, 0.0).sum()
