"""Known-bad jit-purity fixture: one violation class per function.

tests/test_analysis.py asserts the exact line of every finding — keep
line numbers stable when editing.
"""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def inplace_at(x):
    np.add.at(x, 0, 1.0)                    # line 13: in-place scatter
    return x


@jax.jit
def subscript_store(x):
    x = x + 1.0
    x[0] = 2.0                              # line 20: subscript store
    return x


@jax.jit
def mixes_numpy(x):
    y = np.cumsum(x)                        # line 26: np in traced path
    return jnp.asarray(y)


@jax.jit
def traced_branch(x):
    if x.sum() > 0:                         # line 32: traced `if`
        return x
    return -x


@jax.jit
def dynamic_shape(x):
    return jnp.nonzero(x)                   # line 38: dynamic shape


@jax.jit
def one_arg_where(x):
    return jnp.where(x > 0)                 # line 43: 1-arg where
