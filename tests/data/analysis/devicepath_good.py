"""Known-good jit-purity fixture: device-datapath idioms.

The ``sim/devicepath.py`` / ``kernels/wlbvt_select.py`` style — factory
closures feeding ``lax.scan``, masked trash-slot scatters, static
``impl: str`` backend branches — must produce ZERO findings.
"""
import functools

import jax
import jax.numpy as jnp
from jax import lax

PAD = 8          # trash-slot index (ALL_CAPS module constant)


@functools.lru_cache(maxsize=4)
def build_launch(n: int, impl: str):
    """Factory-closed static geometry; the jit root is the closure."""

    def step(state, d):
        tfin, free = state
        tmin = jnp.min(tfin)
        pc = jnp.argmin(jnp.where(tfin == tmin, d, jnp.inf))
        live = tmin < jnp.inf
        # masked scatter aims at the pad slot — no traced branch
        pc_w = jnp.where(live, pc, PAD)
        tfin = tfin.at[pc_w].set(jnp.inf)
        free = free + jnp.where(live, 1, 0)
        return (tfin, free), tmin

    def launch(state, d):
        if impl == "ref":              # `impl: str` is trace-static
            return lax.scan(lambda s, _: step(s, d), state, None, length=n)
        return lax.scan(lambda s, _: step(s, d), state, None, length=n)

    return jax.jit(launch)


def select_pick(prio, queue_len, metric):
    """Masked argmin with eligibility predicate (select-lanes idiom)."""
    elig = queue_len > 0
    masked = jnp.where(elig, metric / prio, jnp.inf)
    idx = jnp.argmin(masked, axis=-1)
    return jnp.where(jnp.any(elig, axis=-1), idx, -1)
