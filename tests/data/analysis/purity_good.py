"""Known-good jit-purity fixture: every allowed idiom in one file.

These patterns must produce ZERO findings — they are the sanctioned
kernel style (xp-generic, static config branches, host-constant math).
"""
import jax
import jax.numpy as jnp
import numpy as np

HIST_GROWTH = 1.5


def commit(xp, staged, totals, floor: float):
    """xp-generic collector kernel: pure, fixed-shape, branch-free."""
    if floor is None:                       # `is` test is trace-static
        floor = 0.0
    lo = np.log(HIST_GROWTH)                # host-constant math, allowed
    mask = xp.where(staged > floor, 1.0, 0.0)
    return totals + staged * mask + lo


def eager_fast_path(xp, counts):
    if xp is np:                            # sanctioned numpy guard
        return np.cumsum(counts)
    return xp.cumsum(counts)


@jax.jit
def doubled(x):
    if x.ndim == 2:                         # shape metadata is static
        return x * 2.0
    return jnp.asarray(x + x)
