"""Known-bad span-balance fixture: a leaked begin, a non-terminal
abandon, an unmatched end, and a magic-number stage."""
from repro.telemetry import trace as TR


def admit(tr, uid, tenant, now):
    tr.span_begin(TR.ST_PU, uid, tenant, now)      # never closed: leak


def finish(tr, uid, now):
    tr.span_end(TR.ST_DMA, uid, now)               # never opened here


def give_up(tr, uid, tenant, now):
    tr.span_begin(TR.ST_FMQ, uid, tenant, now)
    tr.span_abandon(TR.ST_FMQ, uid, now, TR.D_OK)  # non-terminal disp


def magic(tr, uid, tenant, now):
    tr.span_begin(3, uid, tenant, now)             # numeric stage code
