"""Emit + consume sites for every kind declared in kinds.py."""
from .kinds import EventKind


def emit(push):
    push(EventKind.COMPLETE)
    push(EventKind.DROP)


def consume(ev, table):
    if ev.kind == EventKind.COMPLETE:
        return table[EventKind.DROP]
    return None
