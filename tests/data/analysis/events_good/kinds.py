"""Known-good EQ-event fixture: total registry, every kind emitted."""


class EventKind:
    COMPLETE = 1
    DROP = 2


EVENT_DISPOSITIONS = {
    EventKind.COMPLETE: "report: completion counters",
    EventKind.DROP: "telemetry: drop counter",
}
