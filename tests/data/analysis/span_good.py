"""Known-good span-balance fixture: every opened stage is closed or
abandoned with a terminal disposition in this module."""
from repro.telemetry import trace as TR


def admit(tr, uid, tenant, now):
    tr.span_begin(TR.ST_FMQ, uid, tenant, now)


def grant(tr, uid, now, slot):
    tr.span_end(TR.ST_FMQ, uid, now, TR.D_OK, pu=slot)


def drop(tr, uid, now):
    tr.span_abandon(TR.ST_FMQ, uid, now, TR.D_DROP)


def kill(tr, uid, now):
    tr.span_abandon(TR.ST_FMQ, uid, now, disp=TR.D_KILL)


def complete(tr, uid, tenant, now):
    # complete rows need no pairing
    tr.span(TR.ST_EQ, uid, tenant, now, now)
