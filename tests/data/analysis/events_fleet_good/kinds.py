"""Known-good fleet EQ-event fixture: the migration + fabric kinds
(mirrors core/events.py after the fleet plane), every kind registered
with a named consumer and emitted."""


class EventKind:
    MIGRATE_START = 1
    MIGRATE_DONE = 2
    SWITCH_DROP = 3


EVENT_DISPOSITIONS = {
    EventKind.MIGRATE_START: "fleet/engine.py: migration record + trace",
    EventKind.MIGRATE_DONE: "fleet/engine.py: migration record + trace",
    EventKind.SWITCH_DROP: "fleet/switch.py: drop counters + report",
}
