"""Emit + consume sites for every kind declared in kinds.py."""
from .kinds import EventKind


def emit(push):
    push(EventKind.MIGRATE_START)
    push(EventKind.MIGRATE_DONE)
    push(EventKind.SWITCH_DROP)


def consume(ev, table):
    if ev.kind == EventKind.MIGRATE_START:
        return table[EventKind.MIGRATE_DONE]
    return ev.kind == EventKind.SWITCH_DROP
