"""Known-bad EQ-event fixture.

tests/test_analysis.py asserts the exact line of every finding — keep
line numbers stable when editing.

  COMPLETE  — fine (registered, emitted, consumed)
  DROP      — line 13: empty consumer string in the registry
  ORPHAN    — line 8: no registry entry; emitted but never consumed
  GHOST     — line 9: no registry entry; never emitted anywhere
  RETIRED   — line 14: stale registry row (not a declared member)
"""


class EventKind:
    COMPLETE = 1
    DROP = 2
    ORPHAN = 3
    GHOST = 4


EVENT_DISPOSITIONS = {
    EventKind.COMPLETE: "report: completion counters",
    EventKind.DROP: "",
    EventKind.RETIRED: "gone",
}
