"""Emit/consume sites leaving ORPHAN unconsumed and GHOST unemitted."""
from .kinds import EventKind


def emit(push):
    push(EventKind.COMPLETE)
    push(EventKind.DROP)
    push(EventKind.ORPHAN)


def consume(ev):
    return ev.kind == EventKind.COMPLETE
