"""Fixture: well-formed MetricSpec declarations (no findings)."""

LABELS_GLOBAL = ("backend",)

METRICS = (
    MetricSpec("osmosis_arrivals_total", "counter", "total",
               "work items arrived"),
    MetricSpec("osmosis_p99_sojourn_ns", "gauge", "ns",
               "interval p99 sojourn (sim)"),
    MetricSpec("osmosis_p99_sojourn_steps", "gauge", "steps",
               "interval p99 sojourn (serve)"),
    MetricSpec("osmosis_drop_rate_ratio", "gauge", "ratio",
               "dropped fraction of arrivals"),
    MetricSpec("osmosis_queue_depth_count", "gauge", "count",
               "windowed mean backlog"),
    MetricSpec("osmosis_jain_weighted_ratio", "gauge", "ratio",
               "weighted Jain fairness", labels=LABELS_GLOBAL),
)
