"""Known-bad frozen-spec / fixed-shape fixture.

tests/test_analysis.py asserts the exact line of every finding — keep
line numbers stable when editing.
"""


def tweak(spec, scale):
    spec.duration_us = spec.duration_us * scale   # line 9: frozen assign
    return spec


def bump(spec):
    spec.num_tenants += 1                         # line 14: in-place


def sneak(spec, value):
    setattr(spec, "seed", value)                  # line 18: setattr
    object.__setattr__(spec, "seed", value)       # line 19: __setattr__


def collect(xp, values, mask):
    idx = xp.nonzero(mask)                        # line 23: dynamic shape
    picked = values[values > 0]                   # line 24: boolean mask
    hot = xp.where(mask)                          # line 25: 1-arg where
    return idx, picked, hot
