"""Known-bad fleet EQ-event fixture.

tests/test_analysis.py asserts the exact line of every finding — keep
line numbers stable when editing.

  MIGRATE_START — fine (registered, emitted, consumed)
  MIGRATE_DONE  — line 23: empty consumer string in the registry
  SWITCH_DROP   — line 17: no registry entry (emitted + consumed)
  MIGRATE_ABORT — line 18: no registry entry; never emitted anywhere
  DRAINED       — line 24: stale registry row (not a declared member)
"""


class EventKind:
    MIGRATE_START = 1
    MIGRATE_DONE = 2
    SWITCH_DROP = 3
    MIGRATE_ABORT = 4


EVENT_DISPOSITIONS = {
    EventKind.MIGRATE_START: "fleet/engine.py: migration record",
    EventKind.MIGRATE_DONE: "",
    EventKind.DRAINED: "gone",
}
