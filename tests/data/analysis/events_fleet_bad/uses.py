"""Emit/consume sites: SWITCH_DROP emitted without a registry row,
MIGRATE_ABORT never emitted at all."""
from .kinds import EventKind


def emit(push):
    push(EventKind.MIGRATE_START)
    push(EventKind.MIGRATE_DONE)
    push(EventKind.SWITCH_DROP)


def consume(ev):
    if ev.kind == EventKind.SWITCH_DROP:
        return "dropped"
    return ev.kind == EventKind.MIGRATE_START
