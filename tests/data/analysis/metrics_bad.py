"""Fixture: malformed MetricSpec declarations (one finding per line
noted below; exact locations pinned in tests/test_analysis.py)."""

BAD = (
    MetricSpec("OsmosisArrivals_total", "counter", "total",
               "name is CamelCase"),                         # line 5
    MetricSpec("osmosis_latency_seconds", "gauge", "seconds",
               "unit outside the whitelist"),                # line 7
    MetricSpec("osmosis_p99_sojourn_ns", "gauge", "steps",
               "name does not end in the declared unit"),    # line 9
    MetricSpec("osmosis_rate_ratio", "histogram", "ratio",
               "kind outside counter/gauge"),                # line 11
    MetricSpec("osmosis_drops_count", "counter", "count",
               "counter without _total"),                    # line 13
    MetricSpec("osmosis_arrivals_total", "counter", "total",
               "first declaration"),                         # line 15
    MetricSpec("osmosis_arrivals_total", "counter", "total",
               "duplicate name + labelset"),                 # line 17
    MetricSpec(DYNAMIC_NAME, "gauge", "ratio",
               "name must be a literal"),                    # line 19
)
