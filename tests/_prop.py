"""Property-testing compat shim: hypothesis when installed, else a seeded
fallback so tier-1 never dies at collection (hypothesis lives in the
optional ``test`` extra — see pyproject.toml).

The fallback implements exactly the strategy subset our tests use
(integers / floats / booleans / lists / data) and runs each ``@given``
body on a fixed number of deterministically seeded examples — weaker
than hypothesis's shrinking search, but the invariants still get
exercised on randomized inputs.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # fallback: fixed seeded example cases
    HAVE_HYPOTHESIS = False

    import zlib

    import numpy as _np

    _FALLBACK_EXAMPLES = 25

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rng):
            return self._draw_fn(rng)

    class _DataStrategy(_Strategy):
        def __init__(self):
            super().__init__(lambda rng: None)

    class _Data:
        """Stand-in for hypothesis's interactive data object."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.draw(self._rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.randint(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.randint(0, 2)))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.randint(min_size, max_size + 1))
                return [elements.draw(rng) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def data():
            return _DataStrategy()

    st = _Strategies()

    def _materialize(strategy, rng):
        if isinstance(strategy, _DataStrategy):
            return _Data(rng)
        return strategy.draw(rng)

    def given(*gargs, **gkwargs):
        def deco(fn):
            base_seed = zlib.crc32(fn.__name__.encode("utf-8"))

            # NOTE: no functools.wraps — pytest must see a zero-arg
            # signature, not the strategy-filled parameters.
            def wrapper():
                for ex in range(_FALLBACK_EXAMPLES):
                    rng = _np.random.RandomState((base_seed + ex) % (2**31))
                    pos = [_materialize(s, rng) for s in gargs]
                    kw = {k: _materialize(s, rng)
                          for k, s in gkwargs.items()}
                    fn(*pos, **kw)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco
