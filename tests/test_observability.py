"""Streaming observability plane (DESIGN.md §11): metrics bus,
SLO burn-rate audit, OpenMetrics/JSONL export, live dashboard.

Pins the PR's acceptance property: in ``qos_closed_loop`` the victim's
burn-rate SLO_ALERT fires *before* the controller's first AIMD weight
intervention — visible in the EQ stream, the trace plane and
``RunReport.extras['slo_audit']`` — bit-identically on the event-loop
and batched sim datapaths.  Also pins the zero-completion interval
semantics (an idle interval is never a violation) and the exported
OpenMetrics schema against the checked-in goldens.
"""
import json
import os

import numpy as np
import pytest

from repro.telemetry.bus import BusFrame, MetricsBus
from repro.telemetry.metrics import COUNTERS, C_IDX
from repro.telemetry.signals import SignalFrame
from repro.telemetry.slo_audit import SLOAlert, SLOAudit, SLOAuditConfig

HERE = os.path.dirname(os.path.abspath(__file__))
GOLDEN_SIM = os.path.join(HERE, "data", "openmetrics_schema.sim.golden")
GOLDEN_SERVE = os.path.join(HERE, "data",
                            "openmetrics_schema.serve.golden")


def _sig(T=2, p99=None, samples=None):
    z = np.zeros(T)
    return SignalFrame(
        p50=z.copy(), p99=np.asarray(p99, float) if p99 is not None
        else z.copy(),
        ecn_rate=z.copy(), drop_rate=z.copy(), service_debt=z.copy(),
        kv_pressure=z.copy(), occupancy_mean=z.copy(),
        queue_mean=z.copy(), jain_weighted=1.0,
        lat_samples=np.asarray(samples, float) if samples is not None
        else z.copy())


def _frame(t=0.0, seq=0, T=2, alerts=()):
    counts = np.zeros((T, len(COUNTERS)), np.int64)
    counts[:, C_IDX["arrivals"]] = 1
    return BusFrame(t=t, seq=seq, time_unit="ns", backend="sim",
                    signals=_sig(T), counts=counts,
                    interval_counts=counts.copy(),
                    weights=np.ones(T), admit=np.ones(T, bool),
                    alerts=tuple(alerts))


def _counts(T=2, arrivals=(0, 0), completed=(0, 0)):
    c = np.zeros((T, len(COUNTERS)), np.int64)
    c[:, C_IDX["arrivals"]] = arrivals
    c[:, C_IDX["completed"]] = completed
    return c


# ---------------------------------------------------------------------------
# metrics bus
# ---------------------------------------------------------------------------
def test_bus_drop_oldest_bounded_queue():
    bus = MetricsBus()
    sub = bus.subscribe(maxlen=3, name="slow")
    for i in range(7):
        bus.publish(_frame(t=float(i), seq=i))
    assert len(sub) == 3
    assert sub.dropped == 4 and sub.delivered == 7
    assert [f.seq for f in sub.drain()] == [4, 5, 6]   # newest retained
    assert bus.dropped == 4


def test_bus_sinks_and_close():
    class Sink:
        def __init__(self):
            self.frames, self.closed = [], False

        def on_frame(self, fr):
            self.frames.append(fr.seq)

        def close(self):
            self.closed = True

    bus = MetricsBus()
    s = bus.add_sink(Sink())
    bus.publish(_frame(seq=0))
    bus.publish(_frame(seq=1))
    bus.close()
    bus.close()                      # idempotent
    assert s.frames == [0, 1] and s.closed
    with pytest.raises(RuntimeError):
        bus.publish(_frame(seq=2))


def test_subscription_latest():
    bus = MetricsBus()
    sub = bus.subscribe(maxlen=4)
    assert sub.latest() is None
    for i in range(3):
        bus.publish(_frame(seq=i))
    assert sub.latest().seq == 2
    assert len(sub) == 0             # latest() drains


# ---------------------------------------------------------------------------
# SLO audit: interval classification + burn-rate windows
# ---------------------------------------------------------------------------
def test_audit_idle_interval_is_never_a_violation():
    # satellite regression: a zero-completion idle interval reads
    # p99 == 0.0 with lat_samples == 0 and must count as good — burn
    # windows never double-count idleness as violation
    audit = SLOAudit([0.0, 100.0], config=SLOAuditConfig(
        objective=0.9, fast_windows=2, slow_windows=4))
    for i in range(6):
        alerts = audit.observe(
            t=float(i), sig=_sig(p99=[0.0, 0.0], samples=[0, 0]),
            interval_counts=_counts())
        assert alerts == ()
    s = audit.summary()
    assert s["alerts_total"] == 0
    assert s["tenants"][1]["violating_intervals"] == 0
    assert s["tenants"][1]["compliance_pct"] == 100.0
    assert s["tenants"][1]["observed_intervals"] == 0   # idle != observed


def test_audit_latency_violation_fires_fast_then_slow():
    audit = SLOAudit([0.0, 100.0], config=SLOAuditConfig(
        objective=0.9, fast_windows=2, slow_windows=4,
        fast_burn=5.0, slow_burn=2.0))
    bad = dict(sig=_sig(p99=[0.0, 250.0], samples=[0, 8]),
               interval_counts=_counts(arrivals=(0, 8), completed=(0, 8)))
    assert audit.observe(t=1.0, **bad) == ()          # window not full
    alerts = audit.observe(t=2.0, **bad)              # 2/2 bad: burn 10
    assert [a.window for a in alerts] == ["fast"]
    a = alerts[0]
    assert a.tenant == 1 and a.t == 2.0 and a.burn_rate == pytest.approx(10.0)
    assert audit.observe(t=3.0, **bad) == ()          # rising edge only
    alerts = audit.observe(t=4.0, **bad)              # slow window full
    assert [a.window for a in alerts] == ["slow"]


def test_audit_starvation_is_a_violation_and_alert_clears():
    # fast_burn 6.0: one bad of two (burn 5.0) stays quiet, two of two
    # (burn 10.0) fires — so the re-fire needs a fresh two-bad edge
    audit = SLOAudit([100.0], config=SLOAuditConfig(
        objective=0.9, fast_windows=2, slow_windows=2, fast_burn=6.0,
        slow_burn=99.0))
    starved = dict(sig=_sig(T=1, p99=[0.0], samples=[0]),
                   interval_counts=_counts(T=1, arrivals=(5,),
                                           completed=(0,)))
    good = dict(sig=_sig(T=1, p99=[50.0], samples=[5]),
                interval_counts=_counts(T=1, arrivals=(5,), completed=(5,)))
    audit.observe(t=1.0, **starved)
    alerts = audit.observe(t=2.0, **starved)
    assert [a.window for a in alerts] == ["fast"]
    audit.observe(t=3.0, **good)
    audit.observe(t=4.0, **good)                      # alert state clears
    alerts = audit.observe(t=5.0, **starved)
    assert alerts == ()
    alerts = audit.observe(t=6.0, **starved)          # re-fires on new edge
    assert [a.window for a in alerts] == ["fast"]
    s = audit.summary()
    assert s["tenants"][0]["violation_windows"] == [[1.0, 2.0], [5.0, 6.0]]


def test_audit_intervention_attribution():
    class Act:
        def __init__(self, boost, admit):
            self.boost, self.admit = boost, admit

    audit = SLOAudit([0.0, 100.0])
    # first tick that moves a knob counts (neutral pre-state is
    # unit boost / everyone admitted)
    new = audit.note_intervention(8.0, Act(np.array([1.0, 1.5]),
                                           np.array([True, True])))
    assert new == [{"t": 8.0, "tenant": 1, "kind": "aimd_weight",
                    "value": 1.5}]
    new = audit.note_intervention(16.0, Act(np.array([1.0, 1.5]),
                                            np.array([False, True])))
    assert new == [{"t": 16.0, "tenant": 0, "kind": "admission",
                    "value": 0.0}]
    assert audit.note_intervention(24.0, Act(np.array([1.0, 1.5]),
                                             np.array([False, True]))) == []
    s = audit.summary()
    assert s["interventions_total"] == 2
    assert s["tenants"][1]["first_intervention_t"] == 8.0


def test_signalframe_pins_zero_completion_interval():
    # interval differencing: an interval with no new samples reads
    # p50 == p99 == 0.0 and lat_samples == 0 even though cumulative
    # telemetry still holds earlier samples
    from repro.telemetry.metrics import Telemetry
    from repro.telemetry.signals import compute_signals
    tel = Telemetry(2)
    tel.lat(0, 500.0)
    tel.lat(0, 700.0)
    tel.commit()
    base = tel.snapshot()
    sig = compute_signals(tel, prio=np.ones(2), total_occup=np.zeros(2),
                          bvt=np.zeros(2), baseline=base)
    assert sig.lat_samples[0] == 0 and sig.p99[0] == 0.0
    # without the baseline the cumulative view still sees the samples
    cum = compute_signals(tel, prio=np.ones(2), total_occup=np.zeros(2),
                          bvt=np.zeros(2))
    assert cum.lat_samples[0] == 2 and cum.p99[0] > 0.0


# ---------------------------------------------------------------------------
# acceptance: alert precedes the first AIMD intervention, on both
# sim datapaths, bit-identically
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def qos_reports():
    from repro.api import get_scenario
    from repro.api.runtime import run_scenario
    spec = get_scenario("qos_closed_loop", duration_us=120.0)
    return {dp: run_scenario(spec.replace(datapath=dp), "sim")
            for dp in ("event", "batched")}


def test_alert_precedes_first_aimd_intervention(qos_reports):
    rep = qos_reports["event"]
    sa = rep.extras["slo_audit"]
    victim = sa["tenants"]["1"]
    assert victim["first_alert_t"] is not None
    assert victim["first_intervention_t"] is not None
    assert victim["first_alert_t"] < victim["first_intervention_t"]
    assert victim["alert_lead"] > 0
    # the alert is in the EQ stream, before any intervention time
    eq_alerts = [e for e in rep.events if e["kind"] == "slo_alert"
                 and e["tenant"] == 1]
    assert eq_alerts and eq_alerts[0]["time"] == victim["first_alert_t"]
    ivs = [iv for iv in sa["interventions"]
           if iv["kind"] == "aimd_weight" and iv["tenant"] == 1]
    assert ivs and eq_alerts[0]["time"] < ivs[0]["t"]


def test_audit_bit_identical_across_datapaths(qos_reports):
    a = qos_reports["event"].extras["slo_audit"]
    b = qos_reports["batched"].extras["slo_audit"]
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)
    ae = [e for e in qos_reports["event"].events
          if e["kind"] == "slo_alert"]
    be = [e for e in qos_reports["batched"].events
          if e["kind"] == "slo_alert"]
    assert ae == be and ae


def test_alert_and_intervention_land_in_trace(qos_reports):
    from repro.api import get_scenario
    from repro.api.runtime import make_runtime
    from repro.telemetry.trace import K_QOS_INTERVENE, K_SLO_ALERT
    from repro.telemetry.traceview import to_perfetto
    spec = get_scenario("qos_closed_loop", duration_us=120.0)
    rt = make_runtime(spec, "sim", trace=True)
    rep = rt.run(spec)
    rt.flush_trace()
    d = rt.trace.decision_rows()
    t_alert = d["time"][d["kind"] == K_SLO_ALERT]
    t_iv = d["time"][d["kind"] == K_QOS_INTERVENE]
    assert len(t_alert) and len(t_iv)
    sa = rep.extras["slo_audit"]["tenants"]["1"]
    assert float(t_alert.min()) == sa["first_alert_t"]
    # Perfetto: alert + intervention threads render with reason names
    evs = to_perfetto(rt.trace)["traceEvents"]
    marks = {e["name"] for e in evs if e.get("ph") == "i"}
    assert marks & {"BURN_FAST", "BURN_SLOW"}
    assert "AIMD_WEIGHT" in marks


# ---------------------------------------------------------------------------
# cross-backend schema + report validation
# ---------------------------------------------------------------------------
def test_cross_backend_audit_schema_and_round_trip():
    from repro.api import get_scenario, RunReport
    from repro.api.runtime import run_scenario
    from repro.telemetry.slo_audit import SUMMARY_KEYS
    spec = get_scenario("qos_closed_loop", duration_us=80.0)
    reps = {b: run_scenario(spec, b) for b in ("sim", "serve")}
    schemas = {}
    for b, rep in reps.items():
        sa = rep.extras["slo_audit"]
        assert tuple(sorted(sa)) == tuple(sorted(SUMMARY_KEYS))
        assert sa["interval_unit"] == rep.time_unit
        tenant_keysets = {tuple(sorted(row)) for row in
                          sa["tenants"].values()}
        assert len(tenant_keysets) == 1
        schemas[b] = (tuple(sorted(sa)), tenant_keysets.pop())
        # JSON round-trip preserves the audit block exactly
        back = RunReport.from_json(rep.to_json())
        assert back.extras["slo_audit"] == sa
    assert schemas["sim"] == schemas["serve"]


def test_report_validates_slo_audit_schema():
    from repro.api import get_scenario
    from repro.api.runtime import run_scenario
    spec = get_scenario("qos_closed_loop", duration_us=60.0)
    rep = run_scenario(spec, "sim")
    rep.validate()
    broken = dict(rep.extras["slo_audit"])
    del broken["interval_unit"]
    rep.extras["slo_audit"] = broken
    with pytest.raises(ValueError, match="slo_audit missing"):
        rep.validate()
    broken = dict(rep.extras["slo_audit"])
    broken["interval_unit"] = "steps"       # wrong unit for a sim report
    rep.extras["slo_audit"] = broken
    with pytest.raises(ValueError, match="interval_unit"):
        rep.validate()


def test_report_validates_trace_summary_schema():
    from repro.api.report import RunReport
    rep = RunReport(scenario="x", backend="sim", time_unit="ns",
                    duration=1.0, scheduler="wlbvt", arbiter="dwrr",
                    seed=0, jain_pu=1.0, jain_io=1.0,
                    extras={"trace_summary": {"spans_recorded": 1}})
    with pytest.raises(ValueError, match="trace_summary missing"):
        rep.validate()


# ---------------------------------------------------------------------------
# exporters + golden schema
# ---------------------------------------------------------------------------
def test_openmetrics_schema_matches_golden(tmp_path):
    from repro.launch.scenario import run_one
    from repro.telemetry.export import schema_lines
    run_one("qos_closed_loop", "sim", {}, fast=True,
            export_dir=str(tmp_path))
    om = tmp_path / "qos_closed_loop.sim.om.txt"
    with open(om) as f:
        got = schema_lines(f.read())
    with open(GOLDEN_SIM) as f:
        want = [ln for ln in (x.strip() for x in f) if ln]
    assert got == want
    # JSONL: streaming, one valid record per frame, stable names
    jl = tmp_path / "qos_closed_loop.sim.jsonl"
    lines = [json.loads(ln) for ln in open(jl)]
    assert lines
    assert [r["seq"] for r in lines] == list(range(len(lines)))
    for r in lines:
        assert r["backend"] == "sim" and r["time_unit"] == "ns"
        assert "osmosis_p99_sojourn_ns" in r["metrics"]


def test_export_cli_golden_gate(tmp_path):
    from repro.launch.scenario import run_one
    from repro.telemetry.export import main as export_main
    run_one("serve_congestor_victim", "serve", {},
            export_dir=str(tmp_path))
    om = str(tmp_path / "serve_congestor_victim.serve.om.txt")
    assert export_main(["--schema", om, "--golden", GOLDEN_SERVE]) == 0
    assert export_main(["--schema", om, "--golden", GOLDEN_SIM]) == 1


def test_exported_values_track_the_report(tmp_path):
    from repro.launch.scenario import run_one
    rep = run_one("qos_closed_loop", "sim", {}, fast=True,
                  export_dir=str(tmp_path))
    lines = [json.loads(ln)
             for ln in open(tmp_path / "qos_closed_loop.sim.jsonl")]
    last = lines[-1]["metrics"]
    # cumulative counters in the last frame match the final report
    assert last["osmosis_completed_total"]["victim"] == \
        rep.tenants[1].completed
    assert last["osmosis_arrivals_total"]["congestor"] == \
        rep.tenants[0].arrivals


# ---------------------------------------------------------------------------
# dashboard
# ---------------------------------------------------------------------------
def test_dashboard_headless_render():
    from repro.launch.dash import Dashboard, demo_frame, main
    dash = Dashboard(names={0: "aggressor", 1: "victim"}, color=False)
    frame = demo_frame()
    dash.on_frame(frame)             # updates alert markers
    text = dash.render(frame)
    assert "victim" in text and "aggressor" in text
    assert "!F" in text and "ALERT victim" in text
    assert "\x1b[" not in text       # color off: plain text
    assert main(["--headless"]) == 0


def test_dashboard_as_bus_sink(capsys):
    import io
    from repro.launch.dash import Dashboard
    out = io.StringIO()
    bus = MetricsBus()
    bus.add_sink(Dashboard(names={0: "a", 1: "b"}, out=out, color=False))
    alert = SLOAlert(t=1.0, tenant=1, window="fast", burn_rate=10.0,
                     p99=9.0, target=4.0)
    bus.publish(_frame(seq=0))
    bus.publish(_frame(seq=1, alerts=(alert,)))
    bus.close()
    text = out.getvalue()
    assert "frame=1" in text and "alerts_total=1" in text
