import os

# Tests run on the real single CPU device by default; the host-mesh tests
# that need several devices spawn with their own XLA_FLAGS via subprocess,
# EXCEPT the in-process mesh tests below which require the flag before jax
# imports — so set a modest 8-device count for the whole test session.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def host_mesh():
    import jax
    return jax.make_mesh((2, 4), ("data", "model"))


@pytest.fixture(scope="session")
def pod_mesh():
    import jax
    return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
