"""Cycle-level simulator reproduces the paper's qualitative claims."""
import numpy as np
import pytest

from repro.core import FragmentationPolicy, SLOPolicy
from repro.sim.scenarios import (make_tenants, run_congestor_victim_compute,
                                 run_hol_blocking, run_standalone,
                                 service_time_vs_ppb)
from repro.sim.workloads import WORKLOADS, ppb, spin_workload
from repro.sim.traffic import equal_share_traces
from repro.sim.engine import Simulator
from repro.configs.osmosis_pspin import PSPIN


def test_clock_ghz_scales_cycle_costs():
    """Regression for the cycles-vs-ns unit bug the static checker found:
    hardware costs expressed in PU cycles (DMA setup, kernel compute,
    fragmentation overhead) must pass through ``PsPINConfig.cycles_ns``
    before touching the ns event clock.  Before the fix raw cycle counts
    were added onto the clock, which was only correct at the default
    1 GHz; a 2 GHz part must finish a compute-only kernel in exactly
    half the virtual time."""
    from repro.configs.osmosis_pspin import PsPINConfig
    from repro.sim.fastpath import BatchedSimulator
    from repro.sim.traffic import TracePacket

    wl = spin_workload("spin", 2.0)            # pure compute, no IO
    payload = 512 - PSPIN.header_bytes
    cycles = PSPIN.dma_setup_cycles + wl.compute_cycles(payload)
    for cls in (Simulator, BatchedSimulator):
        done = {}
        for ghz in (1.0, 2.0):
            sim = cls(make_tenants([wl]), hw=PsPINConfig(clock_ghz=ghz),
                      record_completions=True)
            res = sim.run([TracePacket(0.0, 0, 512)])
            (tenant, t_done), = res.completions
            assert tenant == 0
            done[ghz] = t_done
        assert done[1.0] == pytest.approx(cycles)       # 1 cycle == 1 ns
        assert done[2.0] == pytest.approx(cycles / 2.0)


def test_cycles_ns_exact_at_default_clock():
    """At 1 GHz the conversion is an exact ``* 1.0`` so historical
    golden traces stay bit-identical."""
    from repro.configs.osmosis_pspin import PsPINConfig
    assert PSPIN.cycles_ns(13) == 13.0
    assert PsPINConfig(clock_ghz=2.0).cycles_ns(13) == 6.5


def test_fig9_wlbvt_fairer_than_rr():
    rr = run_congestor_victim_compute("rr", duration_us=80)
    wl = run_congestor_victim_compute("wlbvt", duration_us=80)
    # RR lets the 2x-costlier congestor take ~2x the PUs (Jain ~0.9);
    # WLBVT restores ~equal occupancy (Jain ~1.0).
    assert wl.jain_pu_timeavg > 0.98
    assert rr.jain_pu_timeavg < wl.jain_pu_timeavg - 0.05


def test_fig9_priority_proportional_shares():
    """2x priority => ~2x PU occupancy under contention (R6 SLO knob)."""
    # cpb sized so each tenant alone demands ~18 of 32 PUs => contention
    wl = spin_workload("spin", 6.0)
    tenants = make_tenants([wl, wl], priorities=[2.0, 1.0])
    trace = equal_share_traces(2, sizes=[512, 512], duration_ns=80_000,
                               seed=0)
    sim = Simulator(tenants, scheduler="wlbvt", record_timeline=True)
    res = sim.run(trace)
    occ = res.timeline["occup"]
    qlen = res.timeline["qlen"]
    # only windows where BOTH tenants are backlogged reflect the contention
    # split (once one drains, work conservation hands over its PUs)
    both = (qlen > 0).all(axis=1)
    sat = occ[both]
    assert len(sat) > 5
    means = sat.mean(axis=0)
    assert means[0] / means[1] == pytest.approx(2.0, rel=0.35)


def test_fig10_fragmentation_resolves_hol_blocking():
    off = run_hol_blocking(FragmentationPolicy(mode="off"), arb="fifo",
                           duration_us=60)
    hw = run_hol_blocking(
        FragmentationPolicy(mode="hardware", fragment_bytes=512),
        duration_us=60)
    # victim (64B transfers) p99 improves by >= 5x (paper: order of magnitude)
    assert off.p99(1) / max(hw.p99(1), 1e-9) > 5.0
    # congestor throughput cost bounded (paper: ~2x worst case)
    assert hw.throughput_gbps(0) > 0.3 * off.throughput_gbps(0)


def test_fig10_software_fragmentation_costs_congestor_throughput():
    hw = run_hol_blocking(
        FragmentationPolicy(mode="hardware", fragment_bytes=512),
        duration_us=60)
    sw = run_hol_blocking(
        FragmentationPolicy(mode="software", fragment_bytes=512),
        duration_us=60)
    # software fragmentation pays per-fragment PU overhead -> <= hw tput
    assert sw.throughput_gbps(0) <= hw.throughput_gbps(0) + 1e-9
    # but still fixes the victim's HoL-blocking
    off = run_hol_blocking(FragmentationPolicy(mode="off"), arb="fifo",
                           duration_us=60)
    assert off.p99(1) / max(sw.p99(1), 1e-9) > 3.0


def test_fig11_osmosis_overhead_bounded_compute():
    """Standalone compute-bound workloads: OSMOSIS within ~3% of baseline."""
    for name in ("aggregate", "reduce"):
        base = run_standalone(name, pkt_size=1024, osmosis=False,
                              duration_us=50)
        osm = run_standalone(name, pkt_size=1024, osmosis=True,
                             duration_us=50)
        t_b = base.stats[0].completed
        t_o = osm.stats[0].completed
        assert t_o >= 0.95 * t_b, (name, t_o, t_b)


def test_watchdog_kills_and_raises_eq_event():
    from repro.core.events import EventKind
    from repro.sim.traffic import make_trace
    wl = spin_workload("hog", cycles_per_byte=50.0)
    tenants = make_tenants([wl], cycle_limits=[100])
    sim = Simulator(tenants)
    res = sim.run(make_trace(0, size=1024, share=0.05, duration_ns=20_000))
    assert res.stats[0].killed > 0
    kinds = {e.kind for e in res.events}
    assert EventKind.CYCLE_BUDGET_EXCEEDED in kinds


def test_fifo_queue_overflow_emits_event():
    from repro.core.events import EventKind
    from repro.sim.traffic import make_trace
    wl = spin_workload("hog", cycles_per_byte=1000.0)
    tenants = make_tenants([wl])
    sim = Simulator(tenants, fifo_capacity=4)
    res = sim.run(make_trace(0, size=64, duration_ns=50_000))
    assert res.stats[0].drops > 0
    assert EventKind.QUEUE_OVERFLOW in {e.kind for e in res.events}


def test_fig3_ppb_classification():
    """Compute-bound kernels exceed PPB at small packets; IO-bound >=256B
    fit (paper Fig. 3)."""
    rows = service_time_vs_ppb([64, 1024])
    by = {(w, p): (svc, budget)
          for w, lst in rows.items() for (p, svc, budget) in lst}
    for w in ("aggregate", "reduce", "histogram", "io_read", "io_write"):
        svc, budget = by[(w, 64)]
        assert svc > budget, w                      # <=64B always congests
    svc, budget = by[("io_read", 1024)]
    assert svc <= budget                            # IO-bound fits PPB
    svc, budget = by[("reduce", 1024)]
    assert svc > budget                             # compute-bound never


def test_control_path_priority():
    """EQ/control traffic bypasses a congested AXI queue (R5)."""
    from repro.sim.traffic import make_trace
    wl = WORKLOADS["io_write"]
    tenants = make_tenants([wl])
    sim = Simulator(tenants,
                    frag=FragmentationPolicy(mode="hardware",
                                             fragment_bytes=512))
    # saturate the AXI with large writes
    trace = make_trace(0, size=4096, share=0.9, duration_ns=30_000)
    done_at = {}
    def cb(t):
        done_at["ctrl"] = t
    sim.run(trace, horizon=5_000.0)
    sim.submit_control(64, cb)
    sim.run([], horizon=None)
    assert "ctrl" in done_at


# ---------------------------------------------------------------------------
# TenantStats: fct semantics + bounded kernel-time reservoir (DESIGN.md §8)
# ---------------------------------------------------------------------------
def test_fct_zero_without_arrivals():
    """Completions with no recorded arrival (packets injected before
    registration) must report fct == 0.0 explicitly — not a silently
    collapsed min() against last_completion."""
    from repro.sim.engine import TenantStats
    st = TenantStats()
    assert st.fct == 0.0                       # nothing happened
    st.last_completion = 500.0                 # completion, no arrival
    assert st.first_arrival == float("inf")
    assert st.fct == 0.0
    st.first_arrival = 120.0                   # normal case
    assert st.fct == pytest.approx(380.0)
    st.first_arrival = 600.0                   # degenerate: never negative
    assert st.fct == 0.0


def test_kernel_time_reservoir_bounded_and_exact_below_cap():
    from repro.sim.engine import KT_RESERVOIR_CAP, TenantStats
    st = TenantStats()
    rng = np.random.default_rng(7)
    vals = rng.uniform(10.0, 1000.0, size=KT_RESERVOIR_CAP + 500)
    for v in vals[:100]:
        st.record_kernel_time(float(v))
    # below the cap the sample is complete: exact percentiles
    assert len(st.kernel_times) == 100
    assert st.kernel_time_percentile(50) == pytest.approx(
        float(np.percentile(vals[:100], 50)))
    for v in vals[100:]:
        st.record_kernel_time(float(v))
    # past the cap: bounded memory, exact count/sum, sane percentiles
    assert len(st.kernel_times) == KT_RESERVOIR_CAP
    assert st.kernel_time_count == len(vals)
    assert st.kernel_time_sum == pytest.approx(sum(float(v) for v in vals))
    assert vals.min() <= st.kernel_time_percentile(99) <= vals.max()
    # deterministic: an identical sequence yields an identical reservoir
    st2 = TenantStats()
    for v in vals:
        st2.record_kernel_time(float(v))
    assert np.array_equal(st.kernel_times, st2.kernel_times)


def test_sim_kernel_times_bounded_end_to_end():
    """A long congested run keeps per-tenant kernel-time memory at the
    reservoir cap while p50/p99 stay exact running-count-aware."""
    from repro.sim.engine import KT_RESERVOIR_CAP
    wl = spin_workload("spin", 0.2)
    tenants = make_tenants([wl, wl])
    trace = equal_share_traces(2, sizes=[64, 64], duration_ns=400_000,
                               seed=3)
    res = Simulator(tenants).run(trace)
    total = sum(res.stats[i].kernel_time_count for i in range(2))
    assert total == sum(res.stats[i].completed + res.stats[i].killed
                       for i in range(2))
    for i in range(2):
        assert len(res.stats[i].kernel_times) <= KT_RESERVOIR_CAP
        if res.stats[i].kernel_time_count:
            assert res.p99(i) >= res.p50(i) > 0.0
