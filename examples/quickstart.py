"""Quickstart: build a model, run a train step, serve a request — the
whole public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core.slo import SLOPolicy
from repro.models.registry import build_model
from repro.serving.engine import Engine, EngineConfig, ModelExecutor
from repro.serving.request import Request
from repro.training.data import make_pipeline
from repro.training.trainer import build_trainer


def main():
    # --- 1. a model (reduced qwen3 config; swap any of the 10 archs) ------
    cfg = smoke_config("qwen3-8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {cfg.name}  ({n/1e6:.2f}M params at smoke scale)")

    # --- 2. three train steps ---------------------------------------------
    trainer = build_trainer(cfg, total_steps=100, warmup_steps=5)
    state = trainer.init_state(jax.random.PRNGKey(0))
    pipe = make_pipeline(cfg, seq_len=64, global_batch=4)
    for _ in range(3):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        state, metrics = trainer.train_step(state, batch)
        print(f"  step {int(metrics['step'])}: "
              f"loss {float(metrics['loss']):.3f}")

    # --- 3. serve two tenants through the OSMOSIS engine -------------------
    ecfg = EngineConfig(max_slots=4, max_len=128, prefill_chunk=16,
                        max_tenants=2)
    eng = Engine(ecfg, executor=ModelExecutor(cfg, ecfg))
    eng.create_ectx(0, SLOPolicy(priority=2.0, kv_quota_tokens=128 * 2),
                    name="premium")
    eng.create_ectx(1, SLOPolicy(priority=1.0, kv_quota_tokens=128 * 2),
                    name="standard")
    for t in (0, 1):
        eng.submit(Request(t, np.arange(1, 17, dtype=np.int32),
                           max_new_tokens=8))
    eng.run_until_idle()
    for r in eng.done:
        print(f"  tenant{r.tenant_id}: generated {r.generated} "
              f"(fct={r.fct} steps)")
    print(f"engine fairness (Jain, time-avg): "
          f"{eng.metrics()['jain_timeavg']:.3f}")


if __name__ == "__main__":
    main()
