"""Paper-figure playground: run the paper's experiments (Figs. 9, 10,
12, 13) through the unified runtime API and print the OSMOSIS-vs-
reference comparison from the portable RunReports.

    PYTHONPATH=src python examples/fairness_demo.py --exp fig9
    PYTHONPATH=src python examples/fairness_demo.py --exp fig10
    PYTHONPATH=src python examples/fairness_demo.py --exp fig13

Each experiment is a registered declarative scenario — list them all
with ``python -m repro.launch.scenario --list``.
"""
import argparse

from repro.api import get_scenario, run_scenario


def _run(name, **params):
    return run_scenario(get_scenario(name, **params), "sim")


def fig9():
    print("Fig 9 — PU fairness, 2x-costlier congestor vs victim")
    for sched in ("rr", "wlbvt"):
        r = _run("fig9_congestor_victim", scheduler=sched, duration_us=120)
        print(f"  {sched:6s} Jain={r.jain_pu:.3f}  "
              f"congestor={r.tenants[0].completed}pkts  "
              f"victim={r.tenants[1].completed}pkts")


def fig10():
    print("Fig 10 — HoL-blocking vs fragment size (victim=64B, "
          "congestor=4KiB egress)")
    base = _run("fig10_hol_blocking", frag_mode="off", arb="fifo",
                duration_us=80)
    print(f"  {'off(fifo)':14s} victim p99={base.tenants[1].p99_latency:7.0f}ns  "
          f"congestor={base.tenants[0].throughput:5.1f}Gbit/s")
    for mode in ("software", "hardware"):
        for fb in (512, 2048):
            r = _run("fig10_hol_blocking", frag_mode=mode, frag_bytes=fb,
                     duration_us=80)
            print(f"  {mode + f'({fb}B)':14s} "
                  f"victim p99={r.tenants[1].p99_latency:7.0f}ns  "
                  f"congestor={r.tenants[0].throughput:5.1f}Gbit/s")


def fig12():
    print("Fig 12 — compute-bound mixture (Reduce+Histogram x "
          "victim/congestor)")
    for sched in ("rr", "wlbvt"):
        r = _run("fig12_compute_mixture", scheduler=sched, duration_us=120)
        fct = [round(r.tenants[i].extra["fct"]) for i in range(4)]
        print(f"  {sched:6s} Jain={r.jain_pu:.3f}  FCTs={fct}")


def fig13():
    print("Fig 13 — IO-bound mixture (DMA read/write x victim/congestor)")
    for sched in ("rr", "wlbvt"):
        r = _run("fig13_io_mixture", scheduler=sched, duration_us=120)
        fct = [round(r.tenants[i].extra["fct"]) for i in range(4)]
        print(f"  {sched:6s} Jain_io={r.jain_io:.3f}  FCTs={fct}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", default="fig9",
                    choices=["fig9", "fig10", "fig12", "fig13", "all"])
    args = ap.parse_args()
    exps = {"fig9": fig9, "fig10": fig10, "fig12": fig12, "fig13": fig13}
    if args.exp == "all":
        for fn in exps.values():
            fn()
            print()
    else:
        exps[args.exp]()


if __name__ == "__main__":
    main()
