"""Paper-figure playground: run the cycle-accurate PsPIN simulator and
print the OSMOSIS-vs-reference comparison for any of the paper's
experiments (Figs. 9, 10, 12, 13) from the command line.

    PYTHONPATH=src python examples/fairness_demo.py --exp fig9
    PYTHONPATH=src python examples/fairness_demo.py --exp fig10
    PYTHONPATH=src python examples/fairness_demo.py --exp fig13
"""
import argparse

from repro.core import FragmentationPolicy
from repro.sim.scenarios import (run_compute_mixture,
                                 run_congestor_victim_compute,
                                 run_hol_blocking, run_io_mixture)


def fig9():
    print("Fig 9 — PU fairness, 2x-costlier congestor vs victim")
    for sched in ("rr", "wlbvt"):
        r = run_congestor_victim_compute(sched, duration_us=120)
        print(f"  {sched:6s} Jain={r.jain_pu_timeavg:.3f}  "
              f"congestor={r.stats[0].completed}pkts  "
              f"victim={r.stats[1].completed}pkts")


def fig10():
    print("Fig 10 — HoL-blocking vs fragment size (victim=64B, "
          "congestor=4KiB egress)")
    base = run_hol_blocking(FragmentationPolicy(mode="off"), arb="fifo",
                            duration_us=80)
    print(f"  {'off(fifo)':14s} victim p99={base.p99(1):7.0f}ns  "
          f"congestor={base.throughput_gbps(0):5.1f}Gbit/s")
    for mode in ("software", "hardware"):
        for fb in (512, 2048):
            r = run_hol_blocking(
                FragmentationPolicy(mode=mode, fragment_bytes=fb),
                duration_us=80)
            print(f"  {mode+f'({fb}B)':14s} victim p99={r.p99(1):7.0f}ns  "
                  f"congestor={r.throughput_gbps(0):5.1f}Gbit/s")


def fig12():
    print("Fig 12 — compute-bound mixture (Reduce+Histogram x "
          "victim/congestor)")
    for sched in ("rr", "wlbvt"):
        r = run_compute_mixture(sched, duration_us=120)
        fct = [round(r.stats[i].fct) for i in range(4)]
        print(f"  {sched:6s} Jain={r.jain_pu_timeavg:.3f}  FCTs={fct}")


def fig13():
    print("Fig 13 — IO-bound mixture (DMA read/write x victim/congestor)")
    for sched in ("rr", "wlbvt"):
        r = run_io_mixture(sched, duration_us=120)
        fct = [round(r.stats[i].fct) for i in range(4)]
        print(f"  {sched:6s} Jain_io={r.jain_io_timeavg:.3f}  FCTs={fct}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", default="fig9",
                    choices=["fig9", "fig10", "fig12", "fig13", "all"])
    args = ap.parse_args()
    exps = {"fig9": fig9, "fig10": fig10, "fig12": fig12, "fig13": fig13}
    if args.exp == "all":
        for fn in exps.values():
            fn()
            print()
    else:
        exps[args.exp]()


if __name__ == "__main__":
    main()
