"""Closed-loop QoS on the serving engine (DESIGN.md §6).

A congestor floods long prompts/generations while a latency-SLO victim
serves short interactive requests.  Run once with static weights and
once with the QoSController adapting WLBVT/DWRR weights from the
telemetry plane's p99 signal; compare the victim's p99 FCT (in steps).

    PYTHONPATH=src python examples/qos_controller_demo.py
"""
import numpy as np

from repro.core.slo import SLOPolicy
from repro.serving.engine import Engine, EngineConfig
from repro.serving.request import Request
from repro.telemetry import QoSController, format_console


def run(controller: bool, seed: int = 0, rounds: int = 120):
    ecfg = EngineConfig(max_slots=8, max_len=512, prefill_chunk=32,
                        max_tenants=4, kv_overcommit=2.0,
                        qos_interval=16 if controller else 0)
    eng = Engine(ecfg)
    eng.create_ectx(0, SLOPolicy(kv_quota_tokens=512 * 8), name="congestor")
    eng.create_ectx(1, SLOPolicy(kv_quota_tokens=512 * 8), name="victim")
    if controller:
        targets = np.zeros(ecfg.max_tenants)
        targets[1] = 30.0            # victim p99 FCT target, engine steps
        eng.attach_controller(QoSController(
            base_weights=np.ones(ecfg.max_tenants), p99_targets=targets))
    rng = np.random.RandomState(seed)
    # congestor: standing backlog (WLBVT's weighted cap only binds while a
    # tenant stays backlogged); victim: steady stream whose slot demand
    # (~5 of 8) slightly exceeds its static fair-share cap (4) — the same
    # regime as the simulator's closed-loop scenario
    for _ in range(16):
        eng.submit(Request(0, rng.randint(1, 90, 192).astype(np.int32),
                           max_new_tokens=64))
    for i in range(rounds):
        if i % 8 == 0:
            eng.submit(Request(
                0, rng.randint(1, 90, 192).astype(np.int32),
                max_new_tokens=64))
        for _ in range(2 + i % 2):     # ~5.6 slots of demand: the victim
            eng.submit(Request(        # stays backlogged, so caps bind
                1, rng.randint(1, 90, 12).astype(np.int32),
                max_new_tokens=8))
        eng.run(4)
    eng.run_until_idle()
    return eng


def main():
    for enabled in (False, True):
        eng = run(enabled)
        rep = eng.telemetry_report()
        victim = rep["tenants"][1]
        print(f"\n=== controller={'on' if enabled else 'off'} ===")
        print(format_console(rep))
        print(f"victim p99 FCT: {victim['p99_latency']:.0f} steps   "
              f"Jain(time-avg): {eng.metrics()['jain_timeavg']:.3f}")


if __name__ == "__main__":
    main()
