"""End-to-end training driver example: a ~100M-param qwen3-family model
trained for a few hundred steps on synthetic Markov data, with sharding
(if multiple devices are forced), grad accumulation, checkpointing and
resume.

    PYTHONPATH=src python examples/train_100m.py --steps 300
    # multi-device data/tensor parallel on forced host devices:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/train_100m.py --steps 300 --mesh 2x4
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.training import checkpoint as CKPT
from repro.training.data import make_pipeline
from repro.training.trainer import build_trainer


def config_100m():
    """qwen3 family scaled to ~100M params."""
    base = get_config("qwen3-8b")
    return dataclasses.replace(
        base, name="qwen3-100m", num_layers=6, d_model=512, num_heads=8,
        num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32_000,
        attn_chunk=256, learning_rate=6e-4)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--grad-accum", type=int, default=2)
    ap.add_argument("--mesh", default="none")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = config_100m()
    mesh = None
    if args.mesh != "none":
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))

    trainer = build_trainer(cfg, mesh=mesh, total_steps=args.steps,
                            warmup_steps=20, grad_accum=args.grad_accum)
    state = trainer.init_state(jax.random.PRNGKey(0))
    import numpy as np
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(state.params))
    print(f"params: {n/1e6:.1f}M   mesh: {args.mesh}")

    pipe = make_pipeline(cfg, args.seq_len, args.global_batch, prefetch=True)
    ckpt = CKPT.AsyncCheckpointer(args.ckpt_dir)
    bshard = None
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        bshard = NamedSharding(mesh, P("data", None))

    t0 = time.time()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        if bshard is not None:
            batch = {k: jax.device_put(v, bshard) for k, v in batch.items()}
        state, m = trainer.train_step(state, batch)
        if (step + 1) % 25 == 0:
            toks = args.global_batch * args.seq_len * (step + 1)
            print(f"step {step+1:4d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.2f}  "
                  f"tok/s {toks/(time.time()-t0):,.0f}")
        if (step + 1) % 100 == 0:
            ckpt.save(state, step + 1,
                      extra={"step": step + 1, "data": pipe.state()})
    ckpt.wait()
    print(f"done; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
