"""Multi-tenant serving with OSMOSIS: the paper's Congestor/Victim
experiment (Figs. 9/12) run through the real engine + a real model.

Three tenants with different SLOs share one continuous-batching engine:
  * tenant 0 "batch"        — long prompts, long outputs (the Congestor)
  * tenant 1 "interactive"  — short prompts, short outputs (the Victim)
  * tenant 2 "premium"      — like interactive but 2x priority

Run with --scheduler rr --arbiter fifo to see the baseline starve the
interactive tenants behind the congestor's prefill fragments.

    PYTHONPATH=src python examples/multi_tenant_serving.py
    PYTHONPATH=src python examples/multi_tenant_serving.py \
        --scheduler rr --arbiter fifo
"""
import argparse

import numpy as np

from repro.configs import smoke_config
from repro.core.events import EventKind
from repro.core.slo import SLOPolicy
from repro.serving.engine import Engine, EngineConfig, ModelExecutor
from repro.serving.request import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--scheduler", default="wlbvt", choices=["wlbvt", "rr"])
    ap.add_argument("--arbiter", default="dwrr", choices=["dwrr", "fifo"])
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    ecfg = EngineConfig(max_slots=6, max_len=256, prefill_chunk=32,
                        prefill_slots_per_step=2, scheduler=args.scheduler,
                        arbiter=args.arbiter, max_tenants=3)
    eng = Engine(ecfg, executor=ModelExecutor(cfg, ecfg))

    eng.create_ectx(0, SLOPolicy(priority=1.0, kv_quota_tokens=256 * 2,
                                 kernel_cycle_limit=240), name="batch")
    eng.create_ectx(1, SLOPolicy(priority=1.0, kv_quota_tokens=256 * 2),
                    name="interactive")
    eng.create_ectx(2, SLOPolicy(priority=2.0, kv_quota_tokens=256 * 2),
                    name="premium")

    rng = np.random.RandomState(0)
    for _ in range(args.requests):
        eng.submit(Request(0, rng.randint(1, 90, 160).astype(np.int32),
                           max_new_tokens=48))
        eng.submit(Request(1, rng.randint(1, 90, 12).astype(np.int32),
                           max_new_tokens=12))
        eng.submit(Request(2, rng.randint(1, 90, 12).astype(np.int32),
                           max_new_tokens=12))
    eng.run_until_idle()

    m = eng.metrics()
    print(f"policy: {args.scheduler}+{args.arbiter}   "
          f"Jain(time-avg)={m['jain_timeavg']:.3f}   "
          f"steps={m['steps']}")
    names = {0: "batch(congestor)", 1: "interactive", 2: "premium(2x)"}
    for t in sorted(m["tenants"]):
        d = m["tenants"][t]
        evs = [e.kind.value for e in eng.poll_events(t)
               if e.kind != EventKind.ADMITTED]
        print(f"  {names[t]:18s} done={d['done']:2d} killed={d['killed']} "
              f"mean_fct={d['mean_fct']:6.1f} steps  events={evs[:3]}")


if __name__ == "__main__":
    main()
