"""Multi-tenant serving with OSMOSIS: the paper's Congestor/Victim
experiment (Figs. 9/12) run through the unified runtime API + a real
model.

Three tenants with different SLOs share one continuous-batching engine
(the registered ``serve_three_class`` scenario):
  * tenant 0 "batch"        — long prompts, long outputs (the Congestor)
  * tenant 1 "interactive"  — short prompts, short outputs (the Victim)
  * tenant 2 "premium"      — like interactive but 2x priority

Run with --scheduler rr --arbiter fifo to see the baseline starve the
interactive tenants behind the congestor's prefill fragments.

    PYTHONPATH=src python examples/multi_tenant_serving.py
    PYTHONPATH=src python examples/multi_tenant_serving.py \
        --scheduler rr --arbiter fifo
"""
import argparse

from repro.api import ServeRuntime, get_scenario
from repro.configs import smoke_config
from repro.core.events import EventKind
from repro.serving.engine import ModelExecutor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--scheduler", default="wlbvt", choices=["wlbvt", "rr"])
    ap.add_argument("--arbiter", default="dwrr", choices=["dwrr", "fifo"])
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    spec = get_scenario("serve_three_class", scheduler=args.scheduler,
                        arbiter=args.arbiter, requests=args.requests)
    rt = ServeRuntime.from_spec(
        spec, executor=lambda ecfg: ModelExecutor(cfg, ecfg))
    rep = rt.run(spec).validate()

    print(f"policy: {rep.scheduler}+{rep.arbiter}   "
          f"Jain(time-avg)={rep.jain_pu:.3f}   steps={rep.duration:.0f}")
    names = {0: "batch(congestor)", 1: "interactive", 2: "premium(2x)"}
    admitted = EventKind.ADMITTED.value
    for t in sorted(rep.tenants):
        r = rep.tenants[t]
        evs = [e["kind"] for e in rep.events
               if e["tenant"] == t and e["kind"] != admitted]
        print(f"  {names[t]:18s} done={r.completed:2d} killed={r.killed} "
              f"mean_fct={r.extra['mean_fct']:6.1f} steps  events={evs[:3]}")


if __name__ == "__main__":
    main()
