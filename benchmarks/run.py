"""Benchmark harness: one entry per paper table/figure + adapted serving
experiment + scheduler-cost scaling.  Prints CSV blocks and a headline
summary per benchmark.  Roofline (benchmarks.roofline) runs separately
after repro.launch.dryrun has produced artifacts.

    PYTHONPATH=src python -m benchmarks.run [--only fig9_fairness]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--fast", action="store_true",
                    help="shorter sim durations")
    args = ap.parse_args(argv)

    from benchmarks import (export_overhead, fleet_throughput, paper_figs,
                            sched_cost, serving_fairness, sim_throughput,
                            sweep_throughput, telemetry_overhead,
                            trace_overhead)
    suite = dict(paper_figs.ALL)
    suite["sched_cost"] = sched_cost.run
    suite["serving_fairness"] = serving_fairness.run
    suite["telemetry_overhead"] = telemetry_overhead.run
    suite["sim_throughput"] = sim_throughput.run
    suite["sweep_throughput"] = sweep_throughput.run
    suite["fleet_throughput"] = fleet_throughput.run
    suite["trace_overhead"] = trace_overhead.run
    suite["export_overhead"] = export_overhead.run

    names = [args.only] if args.only else list(suite)
    headlines = {}
    for name in names:
        fn = suite[name]
        t0 = time.time()
        kw = {}
        if args.fast and name.startswith("fig") and name != "fig3_ppb":
            kw = {"duration_us": 60.0}
        try:
            rows, head = fn(**kw)
        except TypeError:
            rows, head = fn()
        dt = time.time() - t0
        print(f"\n=== {name} ({dt:.1f}s) ===")
        for r in rows:
            print(",".join(str(x) for x in r))
        print(f"--- headline: {json.dumps(head)}")
        headlines[name] = head

    out = os.path.join(os.path.dirname(__file__), "results",
                       "headlines.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    # merge: an --only run must not drop other benchmarks' entries
    merged = {}
    if os.path.exists(out):
        try:
            with open(out) as f:
                merged = json.load(f)
        except (OSError, json.JSONDecodeError):
            merged = {}
    merged.update(headlines)
    with open(out, "w") as f:
        json.dump(merged, f, indent=1)
    print(f"\nwrote {out}")
    _append_history(os.path.dirname(out), headlines)
    return 0


def _append_history(results_dir: str, headlines: dict) -> None:
    """Append this invocation's headlines to a timestamped history log
    and print the numeric deltas against the previous entry, so CI perf
    guards (and humans) see drift without diffing artifacts by hand."""
    import datetime
    path = os.path.join(results_dir, "history.jsonl")
    prev = None
    if os.path.exists(path):
        try:
            with open(path) as f:
                lines = [ln for ln in f if ln.strip()]
            if lines:
                prev = json.loads(lines[-1])
        except (OSError, json.JSONDecodeError):
            prev = None
    entry = {
        "ts": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "headlines": headlines,
    }
    with open(path, "a") as f:
        f.write(json.dumps(entry) + "\n")
    print(f"appended {path}")
    if not prev:
        return
    print(f"--- delta vs previous entry ({prev.get('ts', '?')}):")
    old = prev.get("headlines", {})
    for name, head in headlines.items():
        if name not in old or not isinstance(head, dict):
            continue
        for k, v in head.items():
            ov = old[name].get(k)
            if (isinstance(v, (int, float)) and not isinstance(v, bool)
                    and isinstance(ov, (int, float))
                    and not isinstance(ov, bool) and v != ov):
                print(f"  {name}.{k}: {ov} -> {v} ({v - ov:+g})")


if __name__ == "__main__":
    sys.exit(main())
