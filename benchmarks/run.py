"""Benchmark harness: one entry per paper table/figure + adapted serving
experiment + scheduler-cost scaling.  Prints CSV blocks and a headline
summary per benchmark.  Roofline (benchmarks.roofline) runs separately
after repro.launch.dryrun has produced artifacts.

    PYTHONPATH=src python -m benchmarks.run [--only fig9_fairness]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--fast", action="store_true",
                    help="shorter sim durations")
    args = ap.parse_args(argv)

    from benchmarks import (paper_figs, sched_cost, serving_fairness,
                            sim_throughput, telemetry_overhead)
    suite = dict(paper_figs.ALL)
    suite["sched_cost"] = sched_cost.run
    suite["serving_fairness"] = serving_fairness.run
    suite["telemetry_overhead"] = telemetry_overhead.run
    suite["sim_throughput"] = sim_throughput.run

    names = [args.only] if args.only else list(suite)
    headlines = {}
    for name in names:
        fn = suite[name]
        t0 = time.time()
        kw = {}
        if args.fast and name.startswith("fig") and name != "fig3_ppb":
            kw = {"duration_us": 60.0}
        try:
            rows, head = fn(**kw)
        except TypeError:
            rows, head = fn()
        dt = time.time() - t0
        print(f"\n=== {name} ({dt:.1f}s) ===")
        for r in rows:
            print(",".join(str(x) for x in r))
        print(f"--- headline: {json.dumps(head)}")
        headlines[name] = head

    out = os.path.join(os.path.dirname(__file__), "results",
                       "headlines.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    # merge: an --only run must not drop other benchmarks' entries
    merged = {}
    if os.path.exists(out):
        try:
            with open(out) as f:
                merged = json.load(f)
        except (OSError, json.JSONDecodeError):
            merged = {}
    merged.update(headlines)
    with open(out, "w") as f:
        json.dump(merged, f, indent=1)
    print(f"\nwrote {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
