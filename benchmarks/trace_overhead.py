"""Trace-plane recording overhead: % of event-sim per-packet time.

Times the flight recorder's actual per-packet work directly — the
grant-time slot bookkeeping, the completion-time ``span_packet``
staging, the per-round WLBVT provenance snapshot, the eager drop/reject
rows, and the amortized vectorized ring commit — then scales each cost
by the operation counts of a real ``fig9_congestor_victim`` run and
pins the total against the directly-measured untraced wall time of the
same run.  Direct timing is used instead of with/without run
differencing for the same reason as ``benchmarks.telemetry_overhead``:
the recording cost (a few µs per packet) is far below run-to-run
wall-clock noise on a shared host.  A single differencing pair is still
printed (``diff_check_pct``) as an informational cross-check; it is
noisy and also picks up second-order cache/allocator interference, so
it is not gated.

    PYTHONPATH=src python -m benchmarks.trace_overhead [--smoke]

``--smoke`` runs the reduced-size variant and exits nonzero if the
enabled overhead exceeds the 8% budget or the disabled-path guard cost
exceeds the 1% budget (CI gate).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

BUDGET_ENABLED_PCT = 8.0
BUDGET_DISABLED_PCT = 1.0

# `if self.trace is not None` guard sites crossed per processed packet
# on the event datapath (_arrival, _dispatch, _pop_and_start,
# _start_kernel, _finish_kernel)
GUARD_SITES_PER_PACKET = 5


def _short_spec():
    from repro.api import get_scenario
    spec = get_scenario("fig9_congestor_victim")
    kw = {"duration_us": min(spec.duration_us, 60.0)}
    if spec.horizon_us:
        kw["horizon_us"] = min(spec.horizon_us, 60.0)
    return spec.replace(**kw)


def _run(trace: bool):
    """(wall_s, runtime) for one short fig9 event-datapath run."""
    from repro.api.runtime import make_runtime
    spec = _short_spec()
    rt = make_runtime(spec, "sim", trace=trace, datapath="event")
    t0 = time.perf_counter()
    rt.run(spec)
    if trace:
        rt.flush_trace()
    return time.perf_counter() - t0, rt


def _volumes():
    """Operation counts of the reference run, from its own trace."""
    from repro.telemetry import trace as TR
    wall, rt = _run(trace=True)
    tr = rt.trace
    rows = tr.rows()
    dec = tr.decision_rows()
    stage = rows["stage"]
    n_arr = int(np.sum(stage == TR.ST_ARRIVE))
    n_eq = int(np.sum(stage == TR.ST_EQ))
    n_rounds = int(np.sum(dec["kind"] == TR.K_PU_WLBVT))
    s = tr.trace_summary()
    num_pus = getattr(getattr(rt, "_sim", None), "hw", None)
    num_pus = num_pus.num_pus if num_pus is not None else 8
    return {
        "arrivals": n_arr,
        "completions": n_eq,
        "wlbvt_rounds": n_rounds,
        "eager_spans": max(0, n_arr - n_eq),
        "span_rows": s["spans_recorded"],
        "decision_rows": s["decisions_recorded"],
        "num_tenants": tr.T,
        "num_pus": num_pus,
        "wall_on_s": wall,
    }


class _Pkt:
    __slots__ = ("ecn", "arrival", "meta")

    def __init__(self):
        self.ecn = False
        self.arrival = 0.0
        self.meta = 0


def _time_lifecycle(tr, P: int, iters: int) -> float:
    """Per-completion recording cost: the event engine's arrival uid
    bookkeeping + grant-time slot columns + completion ``span_packet``
    staging, looped exactly as the call sites run it."""
    free = list(range(P - 1, -1, -1))
    s_uid = [0] * P
    s_grant = [0.0] * P
    s_tcomp = [0.0] * P
    s_pkt = [None] * P
    pkt = _Pkt()
    uid = 0
    killed = False
    t0 = time.perf_counter()
    for i in range(iters):
        # arrival
        pkt.meta = uid
        uid += 1
        # grant (_pop_and_start + _start_kernel)
        slot = free.pop()
        s_uid[slot] = pkt.meta
        s_grant[slot] = 1.0
        pkt.meta = slot
        s_pkt[slot] = pkt
        s_tcomp[slot] = 2.0
        # completion (_finish_kernel)
        tr.span_packet(s_uid[slot], 1, slot,
                       5 if killed else 1,
                       2 if pkt.ecn else 1,
                       pkt.arrival, s_grant[slot], s_tcomp[slot], 3.0)
        free.append(slot)
    return (time.perf_counter() - t0) / iters


def _time_rounds(tr, T: int, P: int, iters: int) -> float:
    """Per-WLBVT-round provenance cost (single-pick common case)."""
    from repro.core.wlbvt import WLBVTState
    from repro.telemetry import trace as TR
    st = WLBVTState.create(np.linspace(1.0, 4.0, T))
    st.queue_len[:] = 2
    st.bvt[:] = 3.0
    pick = (min(1, T - 1),)
    t0 = time.perf_counter()
    for i in range(iters):
        TR.record_wlbvt_round(tr, float(i), st, pick, P, TR.K_PU_WLBVT)
    return (time.perf_counter() - t0) / iters


def _time_eager(tr, iters: int) -> float:
    """Per-row cost of an eagerly staged drop/reject ARRIVE span."""
    from repro.telemetry import trace as TR
    t0 = time.perf_counter()
    for i in range(iters):
        tr.span(TR.ST_ARRIVE, i, 0, 1.0, 1.0, TR.D_DROP)
    return (time.perf_counter() - t0) / iters


def _time_guard(iters: int) -> float:
    """Per-site cost of the disabled path: one attribute load plus an
    ``is not None`` branch."""
    pkt = _Pkt()
    pkt.meta = None
    t0 = time.perf_counter()
    for _ in range(iters):
        if pkt.meta is not None:
            raise AssertionError
    return (time.perf_counter() - t0) / iters


def measure(smoke: bool = False):
    from repro.telemetry.trace import TraceRecorder
    vol = _volumes()
    T, P = vol["num_tenants"], vol["num_pus"]
    reps = 2 if smoke else 4
    iters = 20000 if smoke else 50000

    base = min(_run(trace=False)[0] for _ in range(reps))

    t_life = t_round = t_eager = t_guard = float("inf")
    commit_per_row = float("inf")
    for _ in range(3):
        tr = TraceRecorder(T, num_pus=P)
        t_life = min(t_life, _time_lifecycle(tr, P, iters))
        t_round = min(t_round, _time_rounds(tr, T, P, iters))
        t_eager = min(t_eager, _time_eager(tr, iters // 4))
        staged = tr._srows + tr._drows
        t0 = time.perf_counter()
        tr.commit()
        commit_per_row = min(
            commit_per_row, (time.perf_counter() - t0) / max(1, staged))
        t_guard = min(t_guard, _time_guard(iters))

    rows_per_run = vol["span_rows"] + vol["decision_rows"]
    enabled_s = (vol["completions"] * t_life
                 + vol["wlbvt_rounds"] * t_round
                 + vol["eager_spans"] * t_eager
                 + rows_per_run * commit_per_row)
    disabled_s = vol["arrivals"] * GUARD_SITES_PER_PACKET * t_guard
    diff_pct = 100.0 * (vol["wall_on_s"] - base) / base

    head = {
        "enabled_pct": round(100.0 * enabled_s / base, 2),
        "disabled_pct": round(100.0 * disabled_s / base, 3),
        "diff_check_pct": round(diff_pct, 2),   # noisy, informational
        "lifecycle_us": round(t_life * 1e6, 3),
        "wlbvt_round_us": round(t_round * 1e6, 3),
        "commit_us_per_row": round(commit_per_row * 1e6, 4),
        "baseline_us_per_completion":
            round(base / max(1, vol["completions"]) * 1e6, 1),
        "budget_enabled_pct": BUDGET_ENABLED_PCT,
        "budget_disabled_pct": BUDGET_DISABLED_PCT,
    }
    head["within_budget"] = bool(
        head["enabled_pct"] < BUDGET_ENABLED_PCT
        and head["disabled_pct"] < BUDGET_DISABLED_PCT)
    return vol, head


def run(smoke: bool = False):
    vol, head = measure(smoke=smoke)
    rows = [("metric", "value")]
    rows += [(k, v) for k, v in vol.items() if k != "wall_on_s"]
    rows += [(k, v) for k, v in head.items()]
    return rows, head


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced run; nonzero exit if over budget")
    args = ap.parse_args(argv)
    rows, head = run(smoke=args.smoke)
    for r in rows:
        print(",".join(str(x) for x in r))
    print(head)
    if args.smoke and not head["within_budget"]:
        print(f"FAIL: trace overhead enabled={head['enabled_pct']}% "
              f"(budget {BUDGET_ENABLED_PCT}%) "
              f"disabled={head['disabled_pct']}% "
              f"(budget {BUDGET_DISABLED_PCT}%)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
