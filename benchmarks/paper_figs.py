"""Paper-figure reproductions (Figs. 3, 9, 10, 11, 12, 13, 14) — each
function returns CSV-ish rows and a headline dict used by run.py and the
EXPERIMENTS.md table generator."""
from __future__ import annotations

from repro.core import FragmentationPolicy
from repro.sim.scenarios import (run_compute_mixture,
                                 run_congestor_victim_compute,
                                 run_hol_blocking, run_io_mixture,
                                 run_standalone, service_time_vs_ppb)


def fig3_ppb():
    """Service time vs per-packet budget."""
    sizes = [64, 128, 256, 512, 1024, 2048, 4096]
    table = service_time_vs_ppb(sizes)
    rows = [("workload", "pkt_bytes", "service_ns", "ppb_ns", "fits")]
    congested_64 = 0
    for name, lst in table.items():
        for p, svc, budget in lst:
            rows.append((name, p, round(svc, 1), round(budget, 1),
                         int(svc <= budget)))
            if p == 64 and svc > budget:
                congested_64 += 1
    return rows, {"workloads_congested_at_64B": congested_64,
                  "total_workloads": len(table)}


def fig9_fairness(duration_us=150.0):
    rows = [("scheduler", "jain_pu_timeavg", "congestor_pkts",
             "victim_pkts")]
    head = {}
    for sched in ("rr", "wlbvt"):
        r = run_congestor_victim_compute(sched, duration_us=duration_us)
        rows.append((sched, round(r.jain_pu_timeavg, 4),
                     r.stats[0].completed, r.stats[1].completed))
        head[f"jain_{sched}"] = round(r.jain_pu_timeavg, 4)
    head["fairness_gain_pct"] = round(
        100 * (head["jain_wlbvt"] - head["jain_rr"]) / head["jain_rr"], 1)
    return rows, head


def fig10_hol(duration_us=100.0):
    rows = [("mode", "frag_bytes", "victim_p50_ns", "victim_p99_ns",
             "congestor_gbps")]
    base = run_hol_blocking(FragmentationPolicy(mode="off"), arb="fifo",
                            duration_us=duration_us)
    rows.append(("off", 0, round(base.p50(1)), round(base.p99(1)),
                 round(base.throughput_gbps(0), 2)))
    head = {"victim_p99_off": round(base.p99(1))}
    for mode in ("software", "hardware"):
        for fb in (512, 1024, 2048):
            r = run_hol_blocking(
                FragmentationPolicy(mode=mode, fragment_bytes=fb),
                duration_us=duration_us)
            rows.append((mode, fb, round(r.p50(1)), round(r.p99(1)),
                         round(r.throughput_gbps(0), 2)))
            if mode == "hardware" and fb == 512:
                head["victim_p99_hw512"] = round(r.p99(1))
    head["victim_p99_improvement_x"] = round(
        head["victim_p99_off"] / max(head["victim_p99_hw512"], 1e-9), 1)
    return rows, head


def fig11_overheads(duration_us=60.0):
    rows = [("workload", "pkt", "baseline_mpps", "osmosis_mpps",
             "overhead_pct")]
    worst = 0.0
    for name in ("aggregate", "reduce", "histogram", "io_read", "io_write",
                 "filtering"):
        for pkt in (256, 1024, 4096):
            b = run_standalone(name, pkt_size=pkt, osmosis=False,
                               duration_us=duration_us)
            o = run_standalone(name, pkt_size=pkt, osmosis=True,
                               duration_us=duration_us)
            mb = b.stats[0].completed / max(b.time, 1e-9) * 1e3   # Mpps
            mo = o.stats[0].completed / max(o.time, 1e-9) * 1e3
            ov = 100 * (mb - mo) / max(mb, 1e-9)
            worst = max(worst, ov)
            rows.append((name, pkt, round(mb, 1), round(mo, 1),
                         round(ov, 1)))
    return rows, {"worst_overhead_pct": round(worst, 1)}


def fig12_compute_mix(duration_us=150.0):
    rows = [("scheduler", "jain_timeavg", "fct_reduce_victim",
             "fct_reduce_congestor", "fct_hist_victim",
             "fct_hist_congestor")]
    head = {}
    for sched in ("rr", "wlbvt"):
        r = run_compute_mixture(sched, duration_us=duration_us)
        fcts = [round(r.stats[i].fct) for i in range(4)]
        rows.append((sched, round(r.jain_pu_timeavg, 4), *fcts))
        head[f"jain_{sched}"] = round(r.jain_pu_timeavg, 4)
        head[f"fcts_{sched}"] = fcts
    head["fairer_pct"] = round(100 * (head["jain_wlbvt"] - head["jain_rr"])
                               / head["jain_rr"], 1)
    head["fct_gain_pct"] = [
        round(100 * (a - b) / max(a, 1e-9), 1)
        for a, b in zip(head["fcts_rr"], head["fcts_wlbvt"])]
    return rows, head


def fig13_io_mix(duration_us=150.0):
    rows = [("scheduler", "jain_io_timeavg", "fct_rv", "fct_rc",
             "fct_wv", "fct_wc")]
    head = {}
    for sched in ("rr", "wlbvt"):
        r = run_io_mixture(sched, duration_us=duration_us)
        fcts = [round(r.stats[i].fct) for i in range(4)]
        rows.append((sched, round(r.jain_io_timeavg, 4), *fcts))
        head[f"jain_{sched}"] = round(r.jain_io_timeavg, 4)
        head[f"fcts_{sched}"] = fcts
    head["fairer_pct"] = round(100 * (head["jain_wlbvt"] - head["jain_rr"])
                               / max(head["jain_rr"], 1e-9), 1)
    head["victim_fct_gain_pct"] = [
        round(100 * (head["fcts_rr"][i] - head["fcts_wlbvt"][i])
              / max(head["fcts_rr"][i], 1e-9), 1) for i in (0, 2)]
    return rows, head


def fig14_latency_dist(duration_us=150.0):
    rows = [("config", "tenant", "p50_ns", "p99_ns")]
    head = {}
    ref = run_io_mixture("rr", duration_us=duration_us)
    for fb in (1024, 2048):
        r = run_io_mixture("wlbvt",
                           frag=FragmentationPolicy(mode="hardware",
                                                    fragment_bytes=fb),
                           duration_us=duration_us)
        for i, nm in enumerate(("read_victim", "read_congestor",
                                "write_victim", "write_congestor")):
            rows.append((f"osmosis_f{fb}", nm, round(r.p50(i)),
                         round(r.p99(i))))
    for i, nm in enumerate(("read_victim", "read_congestor",
                            "write_victim", "write_congestor")):
        rows.append(("reference", nm, round(ref.p50(i)), round(ref.p99(i))))
    r = run_io_mixture("wlbvt",
                       frag=FragmentationPolicy(mode="hardware",
                                                fragment_bytes=1024),
                       duration_us=duration_us)
    head["victim_kernel_p50_reduction_x"] = round(
        ref.p50(0) / max(r.p50(0), 1e-9), 1)
    head["congestor_kernel_p50_increase_x"] = round(
        r.p50(1) / max(ref.p50(1), 1e-9), 1)
    return rows, head


ALL = {
    "fig3_ppb": fig3_ppb,
    "fig9_fairness": fig9_fairness,
    "fig10_hol": fig10_hol,
    "fig11_overheads": fig11_overheads,
    "fig12_compute_mix": fig12_compute_mix,
    "fig13_io_mix": fig13_io_mix,
    "fig14_latency_dist": fig14_latency_dist,
}
