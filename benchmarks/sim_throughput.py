"""Simulator packet throughput: event-loop vs array-batched data plane.

Measures packets/second of the two simulator datapaths (DESIGN.md §8) on
the fig9-style congestor/victim flood — one fast victim colocated with
spin congestors that burn their watchdog budget while the fully-utilized
400G link floods every FMQ — at T ∈ {4, 32, 128}.  Both paths process
the identical trace to the same fixed horizon and make bit-identical
scheduling decisions (pinned by the golden-trace and property tests);
only the wall-clock differs.

    PYTHONPATH=src python -m benchmarks.sim_throughput [--smoke]

``--smoke`` runs the reduced T=32 row only and exits nonzero if the
batched path is below the 5x perf guard (CI gate: the fast path must
not silently rot).  The full run records the ≥10x T=32 headline.
"""
from __future__ import annotations

import argparse
import sys
import time

GUARD_SPEEDUP_T32 = 5.0          # CI smoke gate
TENANT_COUNTS = (4, 32, 128)


def _tenants(T: int):
    """Fig9-style fleet: one fast victim per 32 tenants, the rest spin
    congestors killed at their 50k-cycle watchdog budget (§7.3)."""
    from repro.core import ECTX, SLOPolicy
    from repro.sim.workloads import spin_workload
    out = []
    for i in range(T):
        if i % 32 == 0:
            wl, limit = spin_workload("victim", 0.6), 0
        else:
            wl, limit = spin_workload(f"congestor{i}", 200.0), 50000
        out.append(ECTX(tenant_id=i, name=wl.name,
                        slo=SLOPolicy(priority=1.0,
                                      kernel_cycle_limit=limit),
                        kernel=wl))
    return out


def _measure(T: int, duration_ns: float, *, fifo_capacity: int = 256,
             seed: int = 0, reps: int = 2):
    """(n_packets, event_s, batched_s, checks) for one tenant count.

    The batched path is timed ``reps`` times (min taken) — it is cheap
    enough to repeat and host noise otherwise dominates the ratio; the
    event path runs once (it is the 10-100x-longer leg)."""
    from repro.sim.engine import Simulator
    from repro.sim.fastpath import BatchedSimulator
    from repro.sim.traffic import equal_share_traces
    trace = equal_share_traces(T, sizes=[512] * T, seed=seed,
                               duration_ns=duration_ns, arrays=True)
    n = len(trace)
    se = Simulator(_tenants(T), fifo_capacity=fifo_capacity)
    t0 = time.perf_counter()
    re = se.run(trace.to_packets(), horizon=duration_ns)
    ev_s = time.perf_counter() - t0
    ba_s, rb = float("inf"), None
    for _ in range(max(1, reps)):
        sb = BatchedSimulator(_tenants(T), fifo_capacity=fifo_capacity)
        t0 = time.perf_counter()
        rb = sb.run(trace, horizon=duration_ns)
        ba_s = min(ba_s, time.perf_counter() - t0)
    agree = all(
        re.stats[i].completed == rb.stats[i].completed
        and re.stats[i].killed == rb.stats[i].killed
        and re.stats[i].drops == rb.stats[i].drops
        and re.stats[i].last_completion == rb.stats[i].last_completion
        for i in range(T)) and len(re.events) == len(rb.events)
    return n, ev_s, ba_s, agree


def _fleet_sweep_row(fast: bool):
    """The 128-tenant x ~10^6-packet registered scenario, batched path
    (the scale the event loop cannot practically reach)."""
    from repro.api import get_scenario, run_scenario
    spec = get_scenario("fleet_sweep")
    if fast:
        spec = spec.replace(duration_us=1024.0, horizon_us=1024.0)
    t0 = time.perf_counter()
    rep = run_scenario(spec, "sim")
    dt = time.perf_counter() - t0
    d = rep.to_dict()["tenants"]
    n = sum(v["arrivals"] for v in d.values())
    return n, dt


def run(*, smoke: bool = False, duration_us: float = 0.0):
    """(rows, headline) in the benchmarks.run harness convention."""
    if not duration_us:
        duration_us = 400.0 if smoke else 3000.0
    counts = (32,) if smoke else TENANT_COUNTS
    rows = [("T", "packets", "event_pkts_per_s", "batched_pkts_per_s",
             "speedup", "decisions_agree")]
    head = {}
    for T in counts:
        n, ev_s, ba_s, agree = _measure(T, duration_us * 1e3)
        speedup = ev_s / ba_s
        rows.append((T, n, round(n / ev_s), round(n / ba_s),
                     round(speedup, 2), agree))
        head[f"speedup_T{T}"] = round(speedup, 2)
        head[f"batched_pkts_per_s_T{T}"] = round(n / ba_s)
        if not agree:
            head["decisions_agree"] = False
    head.setdefault("decisions_agree", True)
    if not smoke:
        n, dt = _fleet_sweep_row(fast=False)
        rows.append(("fleet_sweep(128)", n, "-", round(n / dt), "-", "-"))
        head["fleet_sweep_packets"] = n
        head["fleet_sweep_wall_s"] = round(dt, 1)
    head["guard_speedup_T32"] = GUARD_SPEEDUP_T32
    head["guard_ok"] = bool(head["speedup_T32"] >= GUARD_SPEEDUP_T32
                            and head["decisions_agree"])
    return rows, head


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="T=32 only, short trace; nonzero exit if the "
                         f"batched path is < {GUARD_SPEEDUP_T32}x")
    ap.add_argument("--duration-us", type=float, default=0.0)
    args = ap.parse_args(argv)
    rows, head = run(smoke=args.smoke, duration_us=args.duration_us)
    for r in rows:
        print(",".join(str(x) for x in r))
    print(head)
    if args.smoke and not head["guard_ok"]:
        print(f"FAIL: batched datapath {head['speedup_T32']}x < "
              f"{GUARD_SPEEDUP_T32}x guard at T=32 (or decisions diverged)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
