"""Adapted experiment: OSMOSIS scheduling in the TPU serving engine.

The serving analogue of paper Figs. 9/12: congestor tenants with 4x the
work per request vs interactive victims, WLBVT+DWRR vs RR+FIFO, measured
by time-averaged Jain and per-tenant FCT.  Runs the registered
``serve_congestor_victim`` scenario through the unified runtime API
(scheduling-only NullExecutor, so the numbers isolate policy).
"""
from __future__ import annotations

import numpy as np

from repro.api import get_scenario, run_scenario


def _run(scheduler: str, arbiter: str, seed: int = 0):
    spec = get_scenario("serve_congestor_victim", scheduler=scheduler,
                        arbiter=arbiter, seed=seed)
    return run_scenario(spec, "serve")


def run():
    rows = [("policy", "jain_timeavg", "fct_congestor", "fct_victim")]
    head = {}
    for name, (sched, arb) in {
            "reference(rr+fifo)": ("rr", "fifo"),
            "osmosis(wlbvt+dwrr)": ("wlbvt", "dwrr")}.items():
        rep = _run(sched, arb)
        fc = np.mean([rep.tenants[t].extra["mean_fct"] for t in (0, 1)])
        fv = np.mean([rep.tenants[t].extra["mean_fct"] for t in (2, 3)])
        rows.append((name, round(rep.jain_pu, 4), round(fc, 1),
                     round(fv, 1)))
        head[name] = {"jain": round(rep.jain_pu, 4),
                      "victim_fct": round(fv, 1)}
    ref = head["reference(rr+fifo)"]
    osm = head["osmosis(wlbvt+dwrr)"]
    head["victim_fct_gain_pct"] = round(
        100 * (ref["victim_fct"] - osm["victim_fct"])
        / max(ref["victim_fct"], 1e-9), 1)
    head["fairness_gain_pct"] = round(
        100 * (osm["jain"] - ref["jain"]) / max(ref["jain"], 1e-9), 1)
    return rows, head


if __name__ == "__main__":
    rows, head = run()
    for r in rows:
        print(",".join(str(x) for x in r))
    print(head)
