"""Adapted experiment: OSMOSIS scheduling in the TPU serving engine.

The serving analogue of paper Figs. 9/12: congestor tenants with 4x the
work per request vs interactive victims, WLBVT+DWRR vs RR+FIFO, measured
by time-averaged Jain and per-tenant FCT.  Uses the scheduling-only
executor so the numbers isolate policy (not model compute).
"""
from __future__ import annotations

import numpy as np

from repro.core.slo import SLOPolicy
from repro.serving.engine import Engine, EngineConfig
from repro.serving.request import Request


def _run(scheduler: str, arbiter: str, seed: int = 0):
    ecfg = EngineConfig(max_slots=16, max_len=512, prefill_chunk=64,
                        prefill_slots_per_step=4, scheduler=scheduler,
                        arbiter=arbiter, max_tenants=4)
    eng = Engine(ecfg)
    for t in range(4):   # equal static reservations: 4 slots each (R3)
        eng.create_ectx(t, SLOPolicy(kv_quota_tokens=512 * 4))
    rng = np.random.RandomState(seed)
    for i in range(30):
        # tenants 0-1: congestors (long prompts+outputs); 2-3: victims
        for t in (0, 1):
            eng.submit(Request(t, rng.randint(1, 90, 256).astype(np.int32),
                               max_new_tokens=64))
        for t in (2, 3):
            eng.submit(Request(t, rng.randint(1, 90, 16).astype(np.int32),
                               max_new_tokens=16))
    eng.run_until_idle()
    return eng.metrics()


def run():
    rows = [("policy", "jain_timeavg", "fct_congestor", "fct_victim")]
    head = {}
    for name, (sched, arb) in {
            "reference(rr+fifo)": ("rr", "fifo"),
            "osmosis(wlbvt+dwrr)": ("wlbvt", "dwrr")}.items():
        m = _run(sched, arb)
        fc = np.mean([m["tenants"][t]["mean_fct"] for t in (0, 1)])
        fv = np.mean([m["tenants"][t]["mean_fct"] for t in (2, 3)])
        rows.append((name, round(m["jain_timeavg"], 4), round(fc, 1),
                     round(fv, 1)))
        head[name] = {"jain": round(m["jain_timeavg"], 4),
                      "victim_fct": round(fv, 1)}
    ref = head["reference(rr+fifo)"]
    osm = head["osmosis(wlbvt+dwrr)"]
    head["victim_fct_gain_pct"] = round(
        100 * (ref["victim_fct"] - osm["victim_fct"])
        / max(ref["victim_fct"], 1e-9), 1)
    head["fairness_gain_pct"] = round(
        100 * (osm["jain"] - ref["jain"]) / max(ref["jain"], 1e-9), 1)
    return rows, head


if __name__ == "__main__":
    rows, head = run()
    for r in rows:
        print(",".join(str(x) for x in r))
    print(head)
