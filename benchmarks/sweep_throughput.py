"""Device-sweep throughput: scenarios/second of the accelerator-resident
replica sweep vs the host batched datapath (DESIGN.md §13.4).

Runs a 256-replica seed sweep of an 8-tenant heterogeneous mix (per-
tenant cost slopes, sizes and priorities all differ, so every scheduler
input lane is exercised) through ``repro.sim.devicepath`` — one jit/scan
launch, replicas vmapped — and times the same replicas one-by-one on the
host ``BatchedSimulator``.  A parity leg pins device decisions to the
host bit-for-bit (per-tenant completed/killed/drops, EQ event stream,
telemetry sums) before any rate is reported.

    PYTHONPATH=src python -m benchmarks.sweep_throughput [--smoke]

``--smoke`` shrinks the sweep (R=32) and exits nonzero below a relaxed
guard (CI gate).  The full run records the ≥20x headline.  Steady-state
rate is measured on a second launch of the *same* sweep: replica count
and trace geometry are compiled into the launch, so warming with a
different sweep would recompile inside the timed region.
"""
from __future__ import annotations

# Must precede the first jax import in the process: the sweep step is
# thunk-dispatch bound on CPU without the legacy emitter (~3x).
from repro.xlaenv import tune_cpu_for_scan_sweeps

tune_cpu_for_scan_sweeps()

import argparse
import dataclasses
import sys
import time

GUARD_SPEEDUP = 20.0        # full-run headline gate
SMOKE_GUARD = 5.0           # CI smoke gate (small R amortizes worse)
MIX_TENANTS = 8
SWEEP_REPLICAS = 256
SMOKE_REPLICAS = 32
HOST_REPLICAS = 8           # host leg: timed subset, rate extrapolates
SMOKE_HOST_REPLICAS = 4


def _mix_spec(T: int, duration_us: float, seed: int = 0):
    """Heterogeneous T-tenant mix: distinct cost slope, packet size and
    priority per tenant (no two scheduler lanes look alike)."""
    from repro.api import (ArrivalSpec, ScenarioSpec, TenantSpec,
                           WorkloadSpec)
    tens = tuple(
        TenantSpec(
            f"t{i}",
            workload=WorkloadSpec(name=f"w{i}", compute_base=40.0,
                                  compute_per_byte=0.3 + 0.05 * (i % 7)),
            arrival=ArrivalSpec(size=256 + 64 * (i % 5), share=1.0 / T,
                                seed_offset=i),
            priority=1.0 + (i % 3))
        for i in range(T))
    return ScenarioSpec(name=f"sweep_mix_T{T}", tenants=tens,
                        duration_us=duration_us, seed=seed)


def _host_one(spec, *, record_completions: bool = False):
    """One replica on the host batched datapath (the device's oracle)."""
    from repro.api.runtime import build_traces
    from repro.core.slo import ECTX
    from repro.sim.fastpath import build_simulator
    tenants = [ECTX(tenant_id=i, name=t.name, slo=t.slo(),
                    kernel=t.workload.build())
               for i, t in enumerate(spec.tenants)]
    sim = build_simulator(tenants, datapath="batched",
                          scheduler=spec.scheduler, frag=spec.frag(),
                          arb=spec.arbiter,
                          fifo_capacity=spec.fifo_capacity,
                          record_completions=record_completions)
    ta = build_traces(spec, arrays=True)
    horizon = spec.horizon_us * 1e3 if spec.horizon_us else None
    return sim.run(ta, horizon=horizon)


def _parity(spec) -> bool:
    """Device == host on decisions, EQ stream and telemetry sums."""
    from repro.sim.devicepath import run_device
    h = _host_one(spec, record_completions=True)
    d = run_device(spec, record_completions=True)
    if d.time != h.time or d.completions != h.completions:
        return False
    if ([(e.tenant, e.kind, e.time) for e in d.events]
            != [(e.tenant, e.kind, e.time) for e in h.events]):
        return False
    for i in range(len(spec.tenants)):
        hs, ds = h.stats[i], d.stats[i]
        if any(getattr(ds, f) != getattr(hs, f)
               for f in ("completed", "killed", "drops",
                         "served_payload_bytes", "last_completion",
                         "kernel_time_count", "kernel_time_sum")):
            return False
    return True


def _measure(R: int, H: int, duration_us: float):
    """(pkts_per_replica, compile_s, device_s, host_s_per_replica)."""
    from repro.sim.devicepath import run_sweep_specs
    base = _mix_spec(MIX_TENANTS, duration_us)
    specs = [dataclasses.replace(base, seed=s) for s in range(R)]
    # cold launch = trace + compile + run; warming with a smaller sweep
    # would change the compiled (R, S) geometry and recompile below
    t0 = time.perf_counter()
    res = run_sweep_specs(specs)
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = run_sweep_specs(specs)
    dev_s = time.perf_counter() - t0
    n_pkts = sum(st.completed for st in res[0].stats.values())
    t0 = time.perf_counter()
    for s in specs[:H]:
        _host_one(s)
    host_s = (time.perf_counter() - t0) / H
    return n_pkts, cold_s, dev_s, host_s


def run(*, smoke: bool = False, duration_us: float = 0.0):
    """(rows, headline) in the benchmarks.run harness convention."""
    if not duration_us:
        duration_us = 20.0 if smoke else 24.0
    R = SMOKE_REPLICAS if smoke else SWEEP_REPLICAS
    H = SMOKE_HOST_REPLICAS if smoke else HOST_REPLICAS
    guard = SMOKE_GUARD if smoke else GUARD_SPEEDUP
    parity_ok = _parity(_mix_spec(MIX_TENANTS, duration_us))
    n_pkts, cold_s, dev_s, host_s = _measure(R, H, duration_us)
    dev_rate, host_rate = R / dev_s, 1.0 / host_s
    speedup = dev_rate / host_rate
    rows = [
        ("leg", "replicas", "scenarios_per_s", "pkts_per_s", "wall_s"),
        ("device_cold", R, round(R / cold_s, 1),
         round(n_pkts * R / cold_s), round(cold_s, 3)),
        ("device_steady", R, round(dev_rate, 1),
         round(n_pkts * dev_rate), round(dev_s, 3)),
        ("host_batched", H, round(host_rate, 1),
         round(n_pkts * host_rate), round(host_s * H, 3)),
    ]
    head = {
        "scenarios_per_sec": round(dev_rate, 1),
        "device_pkts_per_sec": round(n_pkts * dev_rate),
        "host_scenarios_per_sec": round(host_rate, 2),
        "speedup": round(speedup, 1),
        "parity_ok": parity_ok,
        "guard_speedup": guard,
        "guard_ok": bool(speedup >= guard and parity_ok),
    }
    return rows, head


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help=f"R={SMOKE_REPLICAS} sweep; nonzero exit below "
                         f"the {SMOKE_GUARD}x guard or on parity loss")
    ap.add_argument("--duration-us", type=float, default=0.0)
    args = ap.parse_args(argv)
    rows, head = run(smoke=args.smoke, duration_us=args.duration_us)
    for r in rows:
        print(",".join(str(x) for x in r))
    print(head)
    if args.smoke and not head["guard_ok"]:
        print(f"FAIL: device sweep {head['speedup']}x < "
              f"{head['guard_speedup']}x guard (or parity diverged)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
