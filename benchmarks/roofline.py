"""Roofline analysis from dry-run artifacts (deliverable g).

Reads benchmarks/results/dryrun/*.json (written by repro.launch.dryrun)
and derives, per (arch x shape x mesh):

  compute_s    = HLO flops/device   / 197 TFLOP/s      (v5e bf16 peak)
  memory_s     = HLO bytes/device   / 819 GB/s         (HBM bw)
  collective_s = collective bytes/device / 50 GB/s     (per-link ICI)

plus the dominant term, MODEL_FLOPS (analytic 6·N·D / 6·N_active·D for
train, 2·N_active·D + attention for inference), the useful-compute ratio
MODEL_FLOPS / (HLO flops x devices), and the headline score

  useful_roofline = (MODEL_FLOPS / devices / peak) / max(terms)

i.e. the fraction of the chip's compute roofline at which *useful* model
flops would execute if the step ran exactly at its binding resource limit.

Usage:  PYTHONPATH=src python -m benchmarks.roofline [--csv out.csv]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

PEAK = 197e12
HBM = 819e9
ICI = 50e9

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs per step (global, forward[+backward])."""
    from repro.configs.base import (GLOBAL_ATTN, LOCAL_ATTN,
                                    active_param_count)
    n_act = active_param_count(cfg)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * S
        matmul = 6.0 * n_act * tokens
        attn = 0.0
        pattern = cfg.pattern_for_layers()
        for kind in pattern:
            if kind == GLOBAL_ATTN:
                ctx = S / 2                       # causal average
            elif kind == LOCAL_ATTN:
                ctx = min(cfg.window_size or S, S) / 2
            else:
                continue
            # qk + pv, fwd+bwd (x3), 2 flops/MAC
            attn += 3 * 2 * 2 * B * S * ctx * cfg.num_heads * cfg.head_dim
        return matmul + attn
    if shape.kind == "prefill":
        tokens = B * S
        matmul = 2.0 * n_act * tokens
        attn = 0.0
        for kind in cfg.pattern_for_layers():
            if kind == GLOBAL_ATTN:
                ctx = S / 2
            elif kind == LOCAL_ATTN:
                ctx = min(cfg.window_size or S, S) / 2
            else:
                continue
            attn += 2 * 2 * B * S * ctx * cfg.num_heads * cfg.head_dim
        return matmul + attn
    # decode: one token against a cache of length S
    tokens = B * 1
    matmul = 2.0 * n_act * tokens
    attn = 0.0
    for kind in cfg.pattern_for_layers():
        if kind == GLOBAL_ATTN:
            ctx = S
        elif kind == LOCAL_ATTN:
            ctx = min(cfg.window_size or S, S)
        else:
            continue
        attn += 2 * 2 * B * ctx * cfg.num_heads * cfg.head_dim
    return matmul + attn


def analyse(rec: dict) -> dict:
    from repro.configs import SHAPES, get_config
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    dev = rec["devices"]
    flops_dev = rec["cost"]["flops"]
    bytes_dev = rec["cost"]["bytes_accessed"]
    coll_dev = rec["collectives"]["total_bytes"]
    compute_s = flops_dev / PEAK
    memory_s = bytes_dev / HBM
    coll_s = coll_dev / ICI
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    useful_ratio = mf / max(flops_dev * dev, 1.0)
    bound = max(terms.values())
    useful_roofline = (mf / dev / PEAK) / bound if bound > 0 else 0.0
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh")},
        "tag": rec.get("tag", ""),
        "devices": dev,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops": mf, "useful_ratio": useful_ratio,
        "useful_roofline": useful_roofline,
        "peak_gib": rec["memory"]["peak_bytes_est"] / 2**30,
        "fits_16g": rec["memory"]["peak_bytes_est"] < 16 * 2**30,
    }


def load_records(tag: str = "", mesh: str = ""):
    recs = []
    for p in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(p) as f:
            rec = json.load(f)
        if rec.get("tag", "") != tag:
            continue
        if mesh and rec.get("mesh") != mesh:
            continue
        recs.append(rec)
    return recs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", default="")
    ap.add_argument("--tag", default="", help="perf-iteration tag filter")
    ap.add_argument("--mesh", default="singlepod",
                    help="singlepod | multipod | '' for both")
    args = ap.parse_args(argv)

    recs = load_records(args.tag, args.mesh)
    if not recs:
        print("no dry-run records found — run repro.launch.dryrun first",
              file=sys.stderr)
        return 1
    rows = []
    for rec in recs:
        if "skipped" in rec:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "skipped": rec["skipped"]})
            continue
        rows.append(analyse(rec))

    hdr = (f"{'arch':26s} {'shape':12s} {'mesh':9s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
           f"{'dominant':>10s} {'useful%':>8s} {'roofl%':>7s} "
           f"{'GiB/dev':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if "skipped" in r:
            print(f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:9s} "
                  f"   [skipped: {r['skipped'][:60]}]")
            continue
        print(f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:9s} "
              f"{r['compute_s']:10.3e} {r['memory_s']:10.3e} "
              f"{r['collective_s']:10.3e} {r['dominant']:>10s} "
              f"{100*r['useful_ratio']:7.1f}% {100*r['useful_roofline']:6.1f}% "
              f"{r['peak_gib']:8.2f}")
    if args.csv:
        import csv as _csv
        keys = [k for k in rows[0] if k != "skipped"]
        with open(args.csv, "w", newline="") as f:
            w = _csv.DictWriter(f, fieldnames=sorted(
                {k for r in rows for k in r}))
            w.writeheader()
            w.writerows(rows)
        print(f"wrote {args.csv}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
