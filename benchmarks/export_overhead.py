"""Observability-plane overhead: % of event-sim wall time.

Times the metrics bus's actual per-interval work directly — one full
``observe_tick`` (telemetry snapshot, signal differencing, SLO audit,
``BusFrame`` publish through a subscriber plus the OpenMetrics and
JSONL sinks) on a real post-run simulator — then scales the cost by the
observation-interval count of a reference ``qos_closed_loop`` run and
pins the total against the directly-measured unobserved wall time of
the same run.  Direct timing is used instead of with/without run
differencing for the same reason as ``benchmarks.trace_overhead``: the
per-interval cost is far below run-to-run wall noise on a shared host
(a single differencing pair is still printed as ``diff_check_pct``,
informational only).

Two gates:

  * enabled  — bus + audit + both exporters attached: < 5% of the
    unobserved run wall.
  * detached — nothing attached: the per-window ``observe_tick``
    early-return (one call + one attribute check): < 1%.

    PYTHONPATH=src python -m benchmarks.export_overhead [--smoke]
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

BUDGET_ENABLED_PCT = 5.0
BUDGET_DETACHED_PCT = 1.0


def _short_spec():
    from repro.api import get_scenario
    spec = get_scenario("qos_closed_loop")
    return spec.replace(duration_us=min(spec.duration_us, 60.0))


def _run(observed: bool, out_dir: str):
    """(wall_s, runtime, frames) for one short qos_closed_loop run."""
    from repro.api.runtime import make_runtime
    from repro.telemetry.bus import MetricsBus
    from repro.telemetry.export import attach_exporters
    spec = _short_spec()
    rt = make_runtime(spec, "sim", datapath="event")
    om = None
    if observed:
        bus = MetricsBus()
        om, _ = attach_exporters(bus, os.path.join(out_dir, "ref"))
        bus.subscribe(name="bench")
        rt.attach_bus(bus)
    t0 = time.perf_counter()
    rt.run(spec)
    wall = time.perf_counter() - t0
    if observed:
        bus.close()
    return wall, rt, (om.frames if om is not None else 0)


def _time_enabled(rt, out_dir: str, iters: int) -> float:
    """Per-interval cost of the fully-enabled path: one real
    ``observe_tick`` on the post-run simulator — snapshot, signals,
    audit, publish to one subscriber + OpenMetrics + JSONL sinks."""
    import numpy as np
    from repro.telemetry.bus import MetricsBus
    from repro.telemetry.export import attach_exporters
    from repro.telemetry.slo_audit import SLOAudit
    sim = rt._sim
    bus = MetricsBus()
    attach_exporters(bus, os.path.join(out_dir, "bench"))
    sub = bus.subscribe(name="bench")
    sim.attach_bus(bus)
    sim.attach_slo_audit(SLOAudit([0.0, 2000.0], time_unit="ns"))
    kv = np.zeros(sim.tel.T)
    t0 = time.perf_counter()
    for i in range(iters):
        sim.observe_tick(t=float(i), prio=sim.st.prio,
                         total_occup=sim.st.total_occup, bvt=sim.st.bvt,
                         kv_pressure=kv)
        if not (i & 0xFF):
            sub.drain()              # as a live consumer would
    dt = (time.perf_counter() - t0) / iters
    bus.close()
    sim.attach_bus(None)
    sim.attach_slo_audit(None)
    return dt


def _time_detached(rt, iters: int) -> float:
    """Per-window cost with nothing attached: the ``observe_tick``
    call + early return."""
    import numpy as np
    sim = rt._sim
    kv = np.zeros(sim.tel.T)
    t0 = time.perf_counter()
    for i in range(iters):
        sim.observe_tick(t=float(i), prio=sim.st.prio,
                         total_occup=sim.st.total_occup, bvt=sim.st.bvt,
                         kv_pressure=kv)
    return (time.perf_counter() - t0) / iters


def measure(smoke: bool = False):
    reps = 2 if smoke else 4
    iters = 300 if smoke else 1000
    det_iters = 20000 if smoke else 50000
    with tempfile.TemporaryDirectory() as tmp:
        wall_on, _, frames = _run(True, tmp)
        base = float("inf")
        rt = None
        for _ in range(reps):
            w, rt, _ = _run(False, tmp)
            base = min(base, w)
        t_on = min(_time_enabled(rt, tmp, iters) for _ in range(3))
        t_off = min(_time_detached(rt, det_iters) for _ in range(3))
    spec = _short_spec()
    windows = int(spec.duration_us * 1e3
                  / rt._sim.io_window_ns) or 1
    vol = {
        "frames_per_run": frames,
        "windows_per_run": windows,
        "wall_on_s": wall_on,
        "wall_off_s": base,
    }
    head = {
        "enabled_pct": round(100.0 * frames * t_on / base, 2),
        "detached_pct": round(100.0 * windows * t_off / base, 3),
        "diff_check_pct": round(100.0 * (wall_on - base) / base, 2),
        "observe_us": round(t_on * 1e6, 2),
        "detached_ns": round(t_off * 1e9, 1),
        "budget_enabled_pct": BUDGET_ENABLED_PCT,
        "budget_detached_pct": BUDGET_DETACHED_PCT,
    }
    head["within_budget"] = bool(
        head["enabled_pct"] < BUDGET_ENABLED_PCT
        and head["detached_pct"] < BUDGET_DETACHED_PCT)
    return vol, head


def run(smoke: bool = False):
    vol, head = measure(smoke=smoke)
    rows = [("metric", "value")]
    rows += [(k, round(v, 6) if isinstance(v, float) else v)
             for k, v in vol.items()]
    rows += [(k, v) for k, v in head.items()]
    return rows, head


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced run; nonzero exit if over budget")
    args = ap.parse_args(argv)
    rows, head = run(smoke=args.smoke)
    for r in rows:
        print(",".join(str(x) for x in r))
    print(head)
    if args.smoke and not head["within_budget"]:
        print(f"FAIL: export overhead enabled={head['enabled_pct']}% "
              f"(budget {BUDGET_ENABLED_PCT}%) "
              f"detached={head['detached_pct']}% "
              f"(budget {BUDGET_DETACHED_PCT}%)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
