"""Scheduler decision cost vs tenant count (paper Figs. 7-8 analogue).

The ASIC numbers (area, 5-cycle decision) don't transfer to a software
runtime; the algorithmic analogue is decision latency scaling with the
number of FMQs.  Three measurements:

  * single-decision latency of the numpy control-plane path and the
    jitted jnp data-plane path (both O(T) vectorized, matching the
    paper's linear area scaling);
  * an engine-level end-to-end decision benchmark: one full slot-fill
    round (k winners, KV-quota caps folded in) via the pre-refactor
    scalar per-tenant Python loop vs. the batched ``select_k`` path,
    for T ∈ {16, 64, 128, 512};
  * a numpy↔jnp parity sweep of ``select_k`` over randomized states
    (integer-valued, so fp32/fp64 must agree exactly).
"""
from __future__ import annotations

import time

import numpy as np


def time_numpy(T: int, iters: int = 2000) -> float:
    from repro.core import wlbvt as W
    st = W.WLBVTState.create(np.ones(T))
    st.queue_len[:] = np.random.randint(0, 3, T)
    st.total_occup[:] = np.random.rand(T) * 100
    st.bvt[:] = np.random.rand(T) * 100 + 1
    t0 = time.perf_counter()
    for _ in range(iters):
        W.select(st, 32)
    return (time.perf_counter() - t0) / iters * 1e9


def time_jnp(T: int, iters: int = 200) -> float:
    import jax
    from repro.core import wlbvt as W
    st = W.init_state_jnp(np.ones(T))
    import jax.numpy as jnp
    st["queue_len"] = jnp.asarray(np.random.randint(0, 3, T), jnp.int32)
    st["total_occup"] = jnp.asarray(np.random.rand(T) * 100, jnp.float32)
    st["bvt"] = jnp.asarray(np.random.rand(T) * 100 + 1, jnp.float32)
    fn = jax.jit(lambda s: W.select_jnp(s, 32))
    fn(st).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(st).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e9


# ---------------------------------------------------------------------------
# engine-level decision round: scalar loop baseline vs batched select_k
# ---------------------------------------------------------------------------
def _mk_round_state(T: int, seed: int = 0):
    from repro.core import wlbvt as W
    rng = np.random.RandomState(seed)
    st = W.WLBVTState.create(rng.choice([0.5, 1.0, 2.0, 4.0], size=T))
    st.queue_len[:] = rng.randint(0, 4, T)
    st.cur_occup[:] = rng.randint(0, 2, T)
    st.total_occup[:] = rng.randint(0, 100, T).astype(float)
    st.bvt[:] = rng.randint(1, 50, T).astype(float)
    caps = rng.randint(1, 5, T)
    return st, caps


def _scalar_loop_round(st, caps, num_pus: int, k: int) -> list:
    """The pre-refactor ``Engine._select``/``_assign_slots`` decision
    path, verbatim: one O(T) Python scan per assigned slot."""
    from repro.core import wlbvt as W
    T = st.prio.shape[0]
    picks = []
    for _ in range(k):
        limit = W.pu_limit(st, num_pus)
        tput = st.tput()
        best, best_m = -1, np.inf
        for i in range(T):
            if st.queue_len[i] <= 0:
                continue
            if st.cur_occup[i] >= limit[i] or st.cur_occup[i] >= caps[i]:
                continue
            m = tput[i] / st.prio[i]
            if m < best_m:
                best, best_m = i, m
        if best < 0:
            break
        st.queue_len[best] -= 1
        st.cur_occup[best] += 1
        picks.append(best)
    return picks


def _time_round(T: int, batched: bool, k: int = 8, num_pus: int = 8,
                iters: int = 200) -> float:
    """ns per full k-winner engine scheduling round."""
    from repro.core import wlbvt as W
    st, caps = _mk_round_state(T)
    ql0, co0 = st.queue_len.copy(), st.cur_occup.copy()
    t0 = time.perf_counter()
    for _ in range(iters):
        st.queue_len[:] = ql0          # restore the round's input state
        st.cur_occup[:] = co0
        if batched:
            W.select_k(st, num_pus, k, cap=caps)
        else:
            _scalar_loop_round(st, caps, num_pus, k)
    return (time.perf_counter() - t0) / iters * 1e9


def engine_decision_rows(Ts=(16, 64, 128, 512)):
    rows = [("num_tenants", "scalar_loop_ns", "batched_ns", "speedup")]
    speedups = {}
    for T in Ts:
        loop_ns = _time_round(T, batched=False)
        batch_ns = _time_round(T, batched=True)
        speedups[T] = loop_ns / max(batch_ns, 1e-9)
        rows.append((T, round(loop_ns), round(batch_ns),
                     round(speedups[T], 2)))
    return rows, speedups


def parity_sweep(Ts=(16, 64, 128, 512), cases: int = 10):
    """numpy vs jitted-jnp select_k on randomized integer-valued states:
    pick sequences must match exactly (fp32/fp64 both exact on ints)."""
    import jax.numpy as jnp
    from repro.core import wlbvt as W
    rows = [("num_tenants", "cases", "pick_mismatches")]
    total_bad = 0
    for T in Ts:
        bad = 0
        for c in range(cases):
            st, caps = _mk_round_state(T, seed=1000 + c)
            sj = {
                "prio": jnp.asarray(st.prio, jnp.float32),
                "total_occup": jnp.asarray(st.total_occup, jnp.float32),
                "bvt": jnp.asarray(st.bvt, jnp.float32),
                "cur_occup": jnp.asarray(st.cur_occup, jnp.int32),
                "queue_len": jnp.asarray(st.queue_len, jnp.int32),
            }
            picks_np = W.select_k(st, 8, 8, cap=caps)
            picks_j, _ = W.select_k_jnp(sj, 8, 8,
                                        cap=jnp.asarray(caps, jnp.int32))
            bad += int(picks_np.tolist() != np.asarray(picks_j).tolist())
        rows.append((T, cases, bad))
        total_bad += bad
    return rows, total_bad


def run():
    rows = [("num_fmqs", "numpy_ns", "jnp_jit_ns")]
    for T in (8, 32, 128, 512, 2048):
        rows.append((T, round(time_numpy(T)), round(time_jnp(T))))
    head = {"decision_ns_at_128_fmqs": rows[3][1]}

    eng_rows, speedups = engine_decision_rows()
    rows.append(("", "", ""))
    rows.extend(eng_rows)
    head["engine_round_speedup_at_T128"] = round(speedups[128], 2)
    head["engine_round_speedup_at_T512"] = round(speedups[512], 2)

    par_rows, total_bad = parity_sweep()
    rows.append(("", "", ""))
    rows.extend(par_rows)
    head["select_k_np_jnp_pick_mismatches"] = total_bad
    return rows, head


if __name__ == "__main__":
    rows, head = run()
    for r in rows:
        print(",".join(str(x) for x in r))
    print(head)
