"""Scheduler decision cost vs tenant count (paper Figs. 7-8 analogue).

The ASIC numbers (area, 5-cycle decision) don't transfer to a software
runtime; the algorithmic analogue is decision latency scaling with the
number of FMQs.  We time the numpy control-plane path and the jitted jnp
data-plane path; both are O(T) vectorized, matching the paper's linear
area scaling, and the serving engine amortizes one decision per slot-fill
over a multi-ms XLA step (the paper hides its 5 cycles under packet DMA).
"""
from __future__ import annotations

import time

import numpy as np


def time_numpy(T: int, iters: int = 2000) -> float:
    from repro.core import wlbvt as W
    st = W.WLBVTState.create(np.ones(T))
    st.queue_len[:] = np.random.randint(0, 3, T)
    st.total_occup[:] = np.random.rand(T) * 100
    st.bvt[:] = np.random.rand(T) * 100 + 1
    t0 = time.perf_counter()
    for _ in range(iters):
        W.select(st, 32)
    return (time.perf_counter() - t0) / iters * 1e9


def time_jnp(T: int, iters: int = 200) -> float:
    import jax
    import jax.numpy as jnp
    from repro.core import wlbvt as W
    st = W.init_state_jnp(np.ones(T))
    st["queue_len"] = jnp.asarray(np.random.randint(0, 3, T), jnp.int32)
    st["total_occup"] = jnp.asarray(np.random.rand(T) * 100, jnp.float32)
    st["bvt"] = jnp.asarray(np.random.rand(T) * 100 + 1, jnp.float32)
    fn = jax.jit(lambda s: W.select_jnp(s, 32))
    fn(st).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(st).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e9


def run():
    rows = [("num_fmqs", "numpy_ns", "jnp_jit_ns")]
    for T in (8, 32, 128, 512, 2048):
        rows.append((T, round(time_numpy(T)), round(time_jnp(T))))
    head = {"decision_ns_at_128_fmqs": rows[3][1]}
    return rows, head


if __name__ == "__main__":
    rows, head = run()
    for r in rows:
        print(",".join(str(x) for x in r))
    print(head)
