"""Fleet-plane throughput: the VOQ/crossbar fabric vs N independent NICs.

Measures packets/second of an N-NIC fleet run (DESIGN.md §12) against
the sum of N independent single-NIC runs processing the identical
per-NIC tenant subsets — at *zero cross-traffic* (every tenant homed on
its own ingress port), so the delta is pure fabric machinery: switch
event processing, epoch-stepped co-simulation, and report merging.

    PYTHONPATH=src python -m benchmarks.fleet_throughput [--smoke]

``--smoke`` runs the reduced N=4 row only and exits nonzero if the
fabric overhead exceeds the 15% perf guard (CI gate: the fleet plane
must stay a thin layer over the per-NIC engines).  The full run adds
the N=8 row and a scenarios/second sweep over the registered fleet
scenario catalog.
"""
from __future__ import annotations

import argparse
import sys
import time

GUARD_MAX_OVERHEAD = 0.15        # CI smoke gate: fabric <15% over N NICs
NIC_COUNTS = (4, 8)
TENANTS_PER_NIC = 4


def _specs(N: int, duration_us: float):
    """(fleet_spec, [per-NIC single-NIC specs]): tenant i is homed on
    NIC i%N (the default placement), so every fabric pair is (k, k) and
    no output sees cross-traffic.  Each baseline NIC runs the *same*
    dense tenant table the fleet engines carry (a migration target must
    exist for every tenant on every NIC) with traffic only for its
    placed tenants — so the delta is pure fabric machinery, not table
    width."""
    import dataclasses
    from repro.api.spec import ArrivalSpec, ScenarioSpec, TenantSpec, WorkloadSpec
    from repro.fleet.spec import FleetSpec
    T = N * TENANTS_PER_NIC
    tenants = tuple(
        TenantSpec(f"t{i}",
                   workload=WorkloadSpec(name=f"t{i}", compute_base=40.0,
                                         compute_per_byte=1.0),
                   arrival=ArrivalSpec(size=512, share=0.03, seed_offset=i))
        for i in range(T))
    fleet = FleetSpec(name="fleet_bench", tenants=tenants, num_nics=N,
                      datapath="batched", duration_us=duration_us)
    subs = [ScenarioSpec(
        name=f"nic{k}",
        tenants=tuple(t if i % N == k else dataclasses.replace(
            t, arrival=dataclasses.replace(t.arrival, share=1e-9))
            for i, t in enumerate(tenants)),
        datapath="batched", duration_us=duration_us)
        for k in range(N)]
    return fleet, subs


def _measure(N: int, duration_us: float, *, reps: int = 3):
    """(n_packets, fleet_s, baseline_s) for one NIC count; the arms are
    timed interleaved ``reps`` times, min taken per arm — host noise
    otherwise dominates the single-digit-percent overhead ratio."""
    from repro.api import run_scenario
    from repro.fleet import run_fleet
    fleet, subs = _specs(N, duration_us)
    run_fleet(fleet, validate=False)               # warm both arms
    for s in subs:
        run_scenario(s, "sim", validate=False)
    fleet_s = base_s = float("inf")
    rep = None
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        rep = run_fleet(fleet, validate=False)
        fleet_s = min(fleet_s, time.perf_counter() - t0)
        t0 = time.perf_counter()
        for s in subs:
            run_scenario(s, "sim", validate=False)
        base_s = min(base_s, time.perf_counter() - t0)
    n = sum(r.arrivals for r in rep.tenants.values())
    return n, fleet_s, base_s


def _scenario_sweep(fast: bool):
    """Wall-clock over the registered fleet scenario catalog (both
    acceptance arms of fleet_migrate) -> (n_scenarios, seconds)."""
    from repro.api import get_scenario
    from repro.fleet import run_fleet
    runs = [("fleet_fabric", {}), ("fleet_incast", {}),
            ("fleet_migrate", {"migrate": True}),
            ("fleet_migrate", {"migrate": False})]
    t0 = time.perf_counter()
    for name, kw in runs:
        spec = get_scenario(name, **kw)
        if fast:
            spec = spec.replace(duration_us=min(spec.duration_us, 60.0))
        run_fleet(spec, validate=False)
    return len(runs), time.perf_counter() - t0


def run(*, smoke: bool = False, duration_us: float = 0.0):
    """(rows, headline) in the benchmarks.run harness convention."""
    if not duration_us:
        duration_us = 120.0 if smoke else 400.0
    counts = (4,) if smoke else NIC_COUNTS
    rows = [("N", "packets", "fleet_pkts_per_s", "baseline_pkts_per_s",
             "overhead_frac")]
    head = {}
    for N in counts:
        n, fleet_s, base_s = _measure(N, duration_us)
        overhead = fleet_s / base_s - 1.0
        rows.append((N, n, round(n / fleet_s), round(n / base_s),
                     round(overhead, 3)))
        head[f"fleet_pkts_per_s_N{N}"] = round(n / fleet_s)
        head[f"overhead_frac_N{N}"] = round(overhead, 3)
    n_sc, sweep_s = _scenario_sweep(fast=smoke)
    rows.append(("catalog", n_sc, "-", "-", round(sweep_s, 2)))
    head["scenarios_per_sec"] = round(n_sc / sweep_s, 2)
    head["guard_max_overhead"] = GUARD_MAX_OVERHEAD
    head["guard_ok"] = bool(head[f"overhead_frac_N{counts[0]}"]
                            < GUARD_MAX_OVERHEAD)
    return rows, head


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="N=4 only, short run; nonzero exit if fabric "
                         f"overhead >= {GUARD_MAX_OVERHEAD:.0%}")
    ap.add_argument("--duration-us", type=float, default=0.0)
    args = ap.parse_args(argv)
    rows, head = run(smoke=args.smoke, duration_us=args.duration_us)
    for r in rows:
        print(",".join(str(x) for x in r))
    print(head)
    if args.smoke and not head["guard_ok"]:
        print(f"FAIL: fleet fabric overhead "
              f"{head['overhead_frac_N4']:.1%} >= "
              f"{GUARD_MAX_OVERHEAD:.0%} guard at N=4 zero cross-traffic")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
