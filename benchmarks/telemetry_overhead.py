"""Telemetry recording overhead: % of serving-engine step time.

Times the per-step telemetry commit path (stage a typical event load →
counter/histogram commit → gauge ring push, i.e. ``Engine._commit_telemetry``)
directly against the steady-state ``Engine.step()`` time, on two data
planes:

  * ``null``  — scheduling-only NullExecutor: microsecond steps, the
    adversarial worst case (informational only);
  * ``model`` — smoke-model jitted data plane: the realistic step time
    the <3% recording budget (ISSUE 2 acceptance) is pinned against.

Direct timing is used instead of with/without step differencing because
the recording cost (~tens of µs) is far below run-to-run step-time noise
on a shared host.

    PYTHONPATH=src python -m benchmarks.telemetry_overhead [--smoke]

``--smoke`` runs the reduced-size variant and exits nonzero if the
model-surface overhead (default numpy backend) exceeds the 3% budget
(CI gate).  The jnp backend is reported informationally: its commits are
jitted device calls whose dispatch latency on a CPU backend dwarfs the
recording work itself.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

BUDGET_PCT = 3.0


def _build_engine(backend: str, *, use_model: bool, steps_hint: int):
    from repro.core.slo import SLOPolicy
    from repro.serving.engine import Engine, EngineConfig, ModelExecutor
    from repro.serving.request import Request
    ecfg = EngineConfig(max_slots=8, max_len=128, prefill_chunk=32,
                        max_tenants=16, kv_overcommit=4.0,
                        telemetry=True, telemetry_backend=backend)
    exe = None
    if use_model:
        from repro.configs import smoke_config
        exe = ModelExecutor(smoke_config("qwen3-8b"), ecfg, rng_seed=0)
    eng = Engine(ecfg, executor=exe)
    rng = np.random.RandomState(0)
    for t in range(4):
        eng.create_ectx(t, SLOPolicy(kv_quota_tokens=128 * 2))
    # standing backlog sized so the engine stays busy through measurement
    for i in range(max(64, steps_hint // 4)):
        t = i % 4
        eng.submit(Request(t, rng.randint(1, 90, 16).astype(np.int32),
                           max_new_tokens=24))
    return eng


def _time_steps(eng, steps: int, warmup: int = 8) -> float:
    """Mean seconds per engine step after warmup."""
    for _ in range(warmup):
        eng.step()
    t0 = time.perf_counter()
    for _ in range(steps):
        eng.step()
    return (time.perf_counter() - t0) / steps


def _stage_typical(tel) -> None:
    """A representative per-step event load: a few arrivals, token
    charges, and two request completions."""
    for t in range(4):
        tel.inc("arrivals", t)
        tel.inc("tokens", t, 8.0)
    tel.lat(0, 12.0)
    tel.lat(1, 30.0)


def _time_commit(eng, iters: int = 300) -> float:
    """Mean seconds per full telemetry commit (stage + flush + window)."""
    for _ in range(8):                       # warm jit caches
        _stage_typical(eng.tel)
        eng._commit_telemetry()
    t0 = time.perf_counter()
    for _ in range(iters):
        _stage_typical(eng.tel)
        eng._commit_telemetry()
    np.asarray(eng.tel.state["counts"])      # fence async device commits
    return (time.perf_counter() - t0) / iters


def measure(use_model: bool, steps: int):
    """(step_s, commit_numpy_s, commit_jnp_s) on one surface."""
    eng = _build_engine("numpy", use_model=use_model, steps_hint=steps * 2)
    step_s = _time_steps(eng, steps)
    commit_np = _time_commit(eng)
    eng_j = _build_engine("jnp", use_model=False, steps_hint=16)
    commit_j = _time_commit(eng_j)
    return step_s, commit_np, commit_j


def run(smoke: bool = False):
    steps = 48 if smoke else 160
    rows = [("surface", "step_us", "commit_us_numpy", "numpy_pct",
             "commit_us_jnp", "jnp_pct")]
    head = {}
    for name, use_model in (("null", False), ("model", True)):
        step_s, c_np, c_j = measure(use_model, steps)
        pct_np = 100.0 * c_np / step_s
        pct_j = 100.0 * c_j / step_s
        rows.append((name, round(step_s * 1e6, 1), round(c_np * 1e6, 1),
                     round(pct_np, 2), round(c_j * 1e6, 1),
                     round(pct_j, 2)))
        head[f"overhead_pct_{name}_numpy"] = round(pct_np, 2)
        head[f"overhead_pct_{name}_jnp"] = round(pct_j, 2)
    head["budget_pct"] = BUDGET_PCT
    head["within_budget"] = bool(
        head["overhead_pct_model_numpy"] < BUDGET_PCT)
    return rows, head


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced run; nonzero exit if over the 3% budget")
    args = ap.parse_args(argv)
    rows, head = run(smoke=args.smoke)
    for r in rows:
        print(",".join(str(x) for x in r))
    print(head)
    if args.smoke and not head["within_budget"]:
        print(f"FAIL: model-surface telemetry overhead "
              f"{head['overhead_pct_model_numpy']}% > {BUDGET_PCT}% budget")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
